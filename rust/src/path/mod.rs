//! The pathwise coordinator — Algorithm 1 (SGL) / Algorithm A1 (aSGL).
//!
//! For each step `λ_k → λ_{k+1}`:
//!
//! 1. screen: candidate groups, then candidate variables (two layers for
//!    DFR; one for sparsegl; exact sphere tests for GAP safe),
//! 2. form the optimization set `O_v = C_v ∪ A_v(λ_k)`,
//! 3. solve the problem *restricted to `O_v`* (warm-started),
//! 4. KKT-check every excluded variable at the new solution; re-enter
//!    violators and re-solve until clean.
//!
//! The coordinator owns warm starts, timing, and all Appendix-D metrics.
//! Dense compute (full gradients, reduced solves) flows through an
//! exchangeable [`Engine`] so alternative backends can serve the hot path;
//! every reduced solve dispatches the configured
//! [`crate::solver::SolverKind`] (FISTA / ATOS / group-major BCD) through
//! the [`crate::solver::Solver`] trait, and reduced gathers record their
//! group-block offsets so the BCD solver's blocks tile the reduced design
//! exactly ([`ReducedDesign::update_grouped`]).
//!
//! ## Persistent workspaces (zero-allocation hot loop)
//!
//! All per-step scratch lives in a [`PathWorkspace`] that persists across λ
//! steps and KKT re-entry rounds: solver buffers ([`SolverWorkspace`]), the
//! incrementally-maintained reduced design ([`ReducedDesign`] — consecutive
//! optimization sets share their sorted prefix, so re-gathers only copy new
//! columns), gradient/residual/mask scratch, and the KKT violation lists.
//! The residual is *carried*: each reduced solve leaves its fitted values
//! `Xβ` in the workspace, and [`Engine::full_gradient_carried`] turns them
//! into the screening/KKT gradient with a single `Xᵀr` pass — no redundant
//! `Xβ` recomputation anywhere in the solve → KKT → re-solve cycle.
//!
//! Whole workspaces are themselves pooled one level up: the CV engine
//! ([`crate::cv::CvEngine`]) keeps one [`PathWorkspace`] per worker thread
//! in a [`crate::parallel::WorkspacePool`] and reuses it across folds,
//! grid cells, and invocations.

pub mod lambda;

pub use lambda::{lambda_max, log_linear_path};

use crate::data::Dataset;
use crate::error::{check_non_negative, check_positive, check_range, DfrError};
use crate::linalg::{DesignRef, ReducedDesign};
use crate::loss::{Loss, LossKind};
use crate::metrics::{PathMetrics, PointMetrics};
use crate::penalty::{AdaptiveWeights, Penalty, RestrictedPenalty};
use crate::screen::{self, RuleKind, ScreenContext};
use crate::solver::{SolveResult, SolveStatus, SolverConfig, SolverWorkspace};
use std::time::Instant;

/// Dense-compute backend. The default native engine runs everything on
/// the in-crate linear algebra; an alternative engine can serve the same
/// operations from external compute (the trait is the seam the engine
/// ablation benchmarks exercise).
pub trait Engine {
    /// Full gradient `∇f(β)` over all p columns (screening / KKT checks).
    fn full_gradient(&self, loss: &Loss, beta: &[f64]) -> Vec<f64> {
        loss.gradient(beta)
    }

    /// Full gradient written into `out`, given coordinator-carried fitted
    /// values `xb = Xβ` and a residual scratch buffer (length n).
    ///
    /// The native engine turns this into a single `Xᵀr` pass with no
    /// allocation and no `Xβ` recomputation; backends that compute from `β`
    /// directly may ignore `xb` — the default implementation routes
    /// through [`Engine::full_gradient`].
    fn full_gradient_carried(
        &self,
        loss: &Loss,
        beta: &[f64],
        xb: &[f64],
        r_scratch: &mut [f64],
        out: &mut [f64],
    ) {
        let _ = (xb, r_scratch);
        let g = self.full_gradient(loss, beta);
        out.copy_from_slice(&g);
    }

    /// Solve the reduced problem (columns already gathered — dense or
    /// centered-sparse, per the source design's kernel variant) using the
    /// caller's solver workspace.
    #[allow(clippy::too_many_arguments)]
    fn solve_reduced(
        &self,
        kind: LossKind,
        x_red: DesignRef<'_>,
        y: &[f64],
        pen: &RestrictedPenalty,
        lam: f64,
        beta0: &[f64],
        cfg: &SolverConfig,
        ws: &mut SolverWorkspace,
    ) -> SolveResult {
        let loss = Loss::new(kind, x_red, y);
        crate::solver::solve_ws(&loss, pen, lam, beta0, cfg, ws)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Pure-Rust backend.
pub struct NativeEngine;

impl Engine for NativeEngine {
    fn full_gradient_carried(
        &self,
        loss: &Loss,
        beta: &[f64],
        xb: &[f64],
        r_scratch: &mut [f64],
        out: &mut [f64],
    ) {
        let _ = beta;
        loss.gradient_from_xb_into(xb, r_scratch, out);
    }
}

/// Reusable state for pathwise fits: pre-sized scratch carried across λ
/// steps, KKT re-entry rounds, and (when reused via
/// [`PathRunner::run_with_workspace`]) whole path fits. Buffers are
/// grow-only; after the first step at full size the hot loop allocates
/// nothing.
#[derive(Clone, Debug, Default)]
pub struct PathWorkspace {
    /// Inner-solver buffers (FISTA/ATOS/BCD iteration state).
    pub solver: SolverWorkspace,
    /// Incrementally-maintained reduced design `X[:, O_v]`.
    pub reduced: ReducedDesign,
    /// Gradient produced each step (swapped with the previous step's).
    pub(crate) grad: Vec<f64>,
    /// Residual scratch (length n).
    pub(crate) r: Vec<f64>,
    /// Carried fitted values `Xβ` at the current solution.
    pub(crate) xb: Vec<f64>,
    /// Reduced warm start gathered from the previous full solution.
    pub(crate) warm: Vec<f64>,
    /// Current solution scattered to full length.
    pub(crate) beta_full: Vec<f64>,
    /// Warm-start copy for the dynamic GAP-safe re-solve.
    pub(crate) beta_warm: Vec<f64>,
    /// Membership mask of the optimization set (length p).
    pub(crate) in_ov: Vec<bool>,
    /// Group membership mask of the optimization set (length m).
    pub(crate) group_mask: Vec<bool>,
    /// Per-group activity scratch for the variable-level KKT check.
    pub(crate) group_active: Vec<bool>,
    /// KKT violation list (reused each round).
    pub(crate) viol: Vec<usize>,
    /// Index-union scratch, rotated with the live `O_v` by swap.
    pub(crate) idx_scratch: Vec<usize>,
}

impl PathWorkspace {
    /// Workspace pre-sized for an (n × p, m groups) problem.
    pub fn new(n: usize, p: usize, m: usize) -> Self {
        let mut ws = Self::default();
        ws.ensure(n, p, m);
        ws
    }

    /// (Re)size every buffer; retained capacity makes this free once the
    /// workspace has seen the largest problem.
    pub fn ensure(&mut self, n: usize, p: usize, m: usize) {
        fn fit_f(v: &mut Vec<f64>, len: usize) {
            v.clear();
            v.resize(len, 0.0);
        }
        fn fit_b(v: &mut Vec<bool>, len: usize) {
            v.clear();
            v.resize(len, false);
        }
        fit_f(&mut self.grad, p);
        fit_f(&mut self.r, n);
        fit_f(&mut self.xb, n);
        fit_f(&mut self.beta_full, p);
        fit_f(&mut self.beta_warm, p);
        self.warm.clear();
        fit_b(&mut self.in_ov, p);
        fit_b(&mut self.group_mask, m);
        fit_b(&mut self.group_active, m);
        self.viol.clear();
        self.idx_scratch.clear();
    }
}

/// Pathwise fit configuration (defaults = Table A1 synthetic column).
#[derive(Clone, Debug, PartialEq)]
pub struct PathConfig {
    /// SGL mixing parameter α ∈ [0, 1] (1 = lasso, 0 = group lasso).
    pub alpha: f64,
    /// Number of λ path points.
    pub path_len: usize,
    /// `λ_l / λ₁` (0.1 synthetic, 0.2 real data).
    pub path_end_ratio: f64,
    /// Inner-solver settings shared by every path point.
    pub solver: SolverConfig,
    /// `(γ₁, γ₂)` for aSGL adaptive weights; `None` = plain SGL.
    pub adaptive: Option<(f64, f64)>,
    /// Safety valve on the KKT re-entry loop.
    pub max_kkt_rounds: usize,
    /// For `GapSafeDyn`: re-screen after this many solver iterations.
    pub dynamic_chunk: usize,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            alpha: 0.95,
            path_len: 50,
            path_end_ratio: 0.1,
            solver: SolverConfig::default(),
            adaptive: None,
            max_kkt_rounds: 20,
            dynamic_chunk: 10,
        }
    }
}

impl PathConfig {
    /// The `(γ₁, γ₂)` exponents an `(adaptive-spec, rule)` combination
    /// actually fits with: `Some` iff the spec requests adaptive weights
    /// or the rule is aSGL-specific, with the paper's `(0.1, 0.1)`
    /// default. Single source of truth shared by
    /// `PathRunner::build_penalty` and the CV engine's shared-weight
    /// precomputation — keep them agreeing by construction.
    pub fn resolve_adaptive(
        adaptive: Option<(f64, f64)>,
        rule: RuleKind,
    ) -> Option<(f64, f64)> {
        if adaptive.is_some() || rule == RuleKind::DfrAsgl {
            Some(adaptive.unwrap_or((0.1, 0.1)))
        } else {
            None
        }
    }

    /// [`PathConfig::resolve_adaptive`] applied to this config.
    pub fn effective_adaptive(&self, rule: RuleKind) -> Option<(f64, f64)> {
        Self::resolve_adaptive(self.adaptive, rule)
    }

    /// Reject NaN/∞/out-of-range numeric knobs before any fit work starts
    /// (run automatically by [`PathRunner::run_with_workspace`]).
    pub fn validate(&self) -> Result<(), DfrError> {
        check_range("alpha", self.alpha, 0.0, 1.0, "in [0, 1]")?;
        if self.path_len == 0 {
            return Err(DfrError::InvalidParameter {
                name: "path_len",
                value: 0.0,
                constraint: "at least 1",
            });
        }
        check_positive("path_end_ratio", self.path_end_ratio)?;
        check_range("path_end_ratio", self.path_end_ratio, 0.0, 1.0, "in (0, 1]")?;
        check_positive("tol", self.solver.tol)?;
        check_range("backtrack", self.solver.backtrack, 1e-6, 1.0 - 1e-6, "in (0, 1)")?;
        check_positive("step_shrink", self.solver.step_shrink)?;
        // ∞ = unlimited is the default, so only NaN and non-positive are out.
        if self.solver.max_seconds.is_nan() || self.solver.max_seconds <= 0.0 {
            return Err(DfrError::InvalidParameter {
                name: "max_seconds",
                value: self.solver.max_seconds,
                constraint: "> 0 (∞ = unlimited)",
            });
        }
        if let Some((g1, g2)) = self.adaptive {
            check_non_negative("gamma1", g1)?;
            check_non_negative("gamma2", g2)?;
        }
        Ok(())
    }
}

/// Result of a pathwise fit.
#[derive(Clone, Debug)]
pub struct PathFit {
    /// Screening rule the fit ran with.
    pub rule: RuleKind,
    /// The λ grid, descending from λ₁ (null model).
    pub lambdas: Vec<f64>,
    /// One full-length coefficient vector per path point.
    pub betas: Vec<Vec<f64>>,
    /// Per-path-point screening/solver metrics (Appendix D.1).
    pub metrics: PathMetrics,
}

impl PathFit {
    /// Number of active variables at the final path point.
    pub fn active_vars_last(&self) -> usize {
        self.betas.last().map(|b| b.iter().filter(|&&x| x != 0.0).count()).unwrap_or(0)
    }

    /// Mean ℓ₂ distance of coefficients to another fit (per path point) —
    /// the paper's "ℓ₂ distance to no screen" solution-quality metric.
    pub fn l2_distance_to(&self, other: &PathFit) -> f64 {
        assert_eq!(self.betas.len(), other.betas.len());
        let mut s = 0.0;
        for (a, b) in self.betas.iter().zip(&other.betas) {
            s += crate::linalg::l2_distance(a, b);
        }
        s / self.betas.len() as f64
    }
}

/// Builder/driver for a pathwise fit of one rule on one dataset.
pub struct PathRunner<'a> {
    dataset: &'a Dataset,
    cfg: PathConfig,
    rule: RuleKind,
    engine: &'a dyn Engine,
    /// Optional externally-fixed λ path (for CV where folds share λs).
    fixed_path: Option<Vec<f64>>,
    /// Precomputed adaptive weights (so repeats/folds can share them).
    weights: Option<AdaptiveWeights>,
    /// Testing aid: recreate the workspace every λ step, so the fit runs
    /// with fresh-allocation semantics (reference for equivalence tests).
    reference_alloc: bool,
}

static NATIVE: NativeEngine = NativeEngine;

impl<'a> PathRunner<'a> {
    pub fn new(dataset: &'a Dataset, cfg: PathConfig) -> Self {
        PathRunner {
            dataset,
            cfg,
            rule: RuleKind::DfrSgl,
            engine: &NATIVE,
            fixed_path: None,
            weights: None,
            reference_alloc: false,
        }
    }

    /// Select the screening rule (default: DFR for SGL).
    pub fn rule(mut self, rule: RuleKind) -> Self {
        self.rule = rule;
        self
    }

    /// Route dense compute through a custom [`Engine`] instead of the
    /// native one.
    pub fn engine(mut self, engine: &'a dyn Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Fit on an externally-fixed λ grid instead of deriving one from the
    /// data (CV folds and paired benches share paths this way).
    pub fn fixed_path(mut self, lambdas: Vec<f64>) -> Self {
        self.fixed_path = Some(lambdas);
        self
    }

    /// Use precomputed adaptive weights instead of deriving them from the
    /// design, so CV folds / repeats can share one computation per
    /// `(design, γ)` pair.
    pub fn weights(mut self, w: AdaptiveWeights) -> Self {
        self.weights = Some(w);
        self
    }

    /// Disable workspace reuse: every λ step gets freshly-allocated
    /// coordinator buffers, and every inner solve (including KKT re-entry
    /// rounds and the dynamic re-solve) gets fresh solver buffers and a
    /// cold reduced-design gather. Slower by construction; exists so tests
    /// can prove buffer reuse never changes solutions.
    pub fn reference_alloc(mut self, on: bool) -> Self {
        self.reference_alloc = on;
        self
    }

    /// Build the penalty this run will use (aSGL iff
    /// [`PathConfig::effective_adaptive`] says so).
    pub fn build_penalty(&self) -> Penalty {
        let groups = self.dataset.groups.clone();
        if let Some((g1, g2)) = self.cfg.effective_adaptive(self.rule) {
            let aw = self
                .weights
                .clone()
                .unwrap_or_else(|| AdaptiveWeights::from_design(&self.dataset.x, &groups, g1, g2));
            Penalty::asgl(groups, self.cfg.alpha, aw.v, aw.w)
        } else {
            Penalty::sgl(groups, self.cfg.alpha)
        }
    }

    /// Run the pathwise fit with a private workspace.
    pub fn run(&self) -> anyhow::Result<PathFit> {
        let ds = self.dataset;
        let mut ws = PathWorkspace::new(ds.n(), ds.p(), ds.m());
        self.run_with_workspace(&mut ws)
    }

    /// Run the pathwise fit reusing the caller's workspace (benches, CV
    /// folds, and repeated fits amortize all buffer allocation this way;
    /// the workspace self-heals if the dataset or its shape changed).
    pub fn run_with_workspace(&self, ws: &mut PathWorkspace) -> anyhow::Result<PathFit> {
        self.cfg.validate()?;
        let ds = self.dataset;
        let pen = self.build_penalty();
        let kind = LossKind::for_response(ds.response);
        let loss = Loss::new(kind, &ds.x, &ds.y);
        let p = ds.p();
        let m = ds.m();
        let n = ds.n();
        ws.ensure(n, p, m);

        let start_total = Instant::now();
        let grad0 = self.engine.full_gradient(&loss, &vec![0.0; p]);
        let lambdas = match &self.fixed_path {
            Some(l) => l.clone(),
            None => {
                let lam1 = lambda_max(&pen, &grad0);
                log_linear_path(lam1, self.cfg.path_len, self.cfg.path_end_ratio)
            }
        };
        let l = lambdas.len();

        let mut betas: Vec<Vec<f64>> = Vec::with_capacity(l);
        let mut metrics = PathMetrics {
            p,
            m,
            // A safe rule on logistic loss screens nothing (squared-loss
            // certificates only) — record the degradation up front so
            // callers see it instead of a silently unscreened fit.
            screening_fallback: self.rule.logistic_fallback()
                && ds.response == crate::data::Response::Logistic,
            ..Default::default()
        };

        // β̂(λ₁): λ₁ generates the null model by construction.
        let t0 = Instant::now();
        betas.push(vec![0.0; p]);
        metrics.points.push(PointMetrics {
            lambda: lambdas[0],
            status: SolveStatus::Converged,
            kkt_residual: crate::screen::kkt::stationarity_residual(
                &pen,
                &grad0,
                &vec![0.0; p],
                lambdas[0],
            ),
            fit_seconds: t0.elapsed().as_secs_f64(),
            ..Default::default()
        });

        let mut grad_prev = grad0;
        // The live optimization set; rotated with `ws.idx_scratch` so the
        // KKT re-entry unions never allocate after warm-up.
        let mut o_v: Vec<usize> = Vec::new();
        for k in 0..l - 1 {
            if self.reference_alloc {
                *ws = PathWorkspace::new(n, p, m);
            }
            let t_point = Instant::now();
            let lam_prev = lambdas[k];
            let lam_next = lambdas[k + 1];
            let beta_prev = &betas[k];
            let active_prev = screen::active_vars(beta_prev);

            // --- Screening ---
            let ctx = ScreenContext {
                penalty: &pen,
                grad_prev: &grad_prev,
                beta_prev,
                lambda_prev: lam_prev,
                lambda_next: lam_next,
                x: (&ds.x).into(),
                y: &ds.y,
                response: ds.response,
            };
            let cands = screen::screen(self.rule, &ctx);
            let c_v = cands.vars.len();
            let c_g = cands.groups.len();

            // Optimization set = candidates ∪ previously active.
            screen::union_sorted_into(&cands.vars, &active_prev, &mut o_v);
            if o_v.is_empty() {
                // Null model survives this step — nothing to solve. The
                // carried fitted values are identically zero.
                let beta_null = vec![0.0; p];
                ws.xb.fill(0.0);
                self.engine.full_gradient_carried(
                    &loss,
                    &beta_null,
                    &ws.xb,
                    &mut ws.r,
                    &mut ws.grad,
                );
                std::mem::swap(&mut grad_prev, &mut ws.grad);
                metrics.points.push(PointMetrics {
                    lambda: lam_next,
                    c_v,
                    c_g,
                    status: SolveStatus::Converged,
                    kkt_residual: crate::screen::kkt::stationarity_residual(
                        &pen, &grad_prev, &beta_null, lam_next,
                    ),
                    fit_seconds: t_point.elapsed().as_secs_f64(),
                    ..Default::default()
                });
                betas.push(beta_null);
                continue;
            }

            // --- Solve + KKT loop ---
            let mut kkt_violations = 0usize;
            let mut kkt_rounds = 0usize;
            let mut solver_iterations = 0usize;
            let mut status;
            let mut rounds = 0usize;
            loop {
                rounds += 1;
                let res = self.solve_on(&pen, kind, &loss, &o_v, beta_prev, lam_next, ws);
                solver_iterations += res.iterations;
                status = res.status;
                // Residual-carried gradient: one Xᵀr pass over the fitted
                // values the solve just produced.
                self.engine.full_gradient_carried(
                    &loss,
                    &ws.beta_full,
                    &ws.xb,
                    &mut ws.r,
                    &mut ws.grad,
                );

                if !self.rule.needs_kkt() {
                    // Safe-rule fast path: exact rules (GAP safe, TLFre,
                    // no-screen) certify every exclusion, so the
                    // violation→re-entry loop is skipped entirely — zero
                    // KKT rounds recorded, one reduced solve per λ. The
                    // regression test in `rust/tests/screening_safety.rs`
                    // pins both halves of that claim.
                    break;
                }
                self.kkt_check_into(&pen, lam_next, &o_v, ws);
                if ws.viol.is_empty() {
                    break;
                }
                kkt_violations += ws.viol.len();
                kkt_rounds += 1;
                if rounds > self.cfg.max_kkt_rounds {
                    // Degradation ladder, screening rung: re-entry refused
                    // to settle within the cap, so instead of silently
                    // returning a possibly-non-optimal β, certify by
                    // solving the *full* problem (no screening) once from
                    // the current iterate, and say so via `KktCapHit`.
                    let full: Vec<usize> = (0..p).collect();
                    ws.beta_warm.copy_from_slice(&ws.beta_full);
                    let warm = std::mem::take(&mut ws.beta_warm);
                    let fres = self.solve_on(&pen, kind, &loss, &full, &warm, lam_next, ws);
                    ws.beta_warm = warm;
                    solver_iterations += fres.iterations;
                    status = fres.status.worst(SolveStatus::KktCapHit);
                    self.engine.full_gradient_carried(
                        &loss,
                        &ws.beta_full,
                        &ws.xb,
                        &mut ws.r,
                        &mut ws.grad,
                    );
                    o_v = full;
                    break;
                }
                screen::union_sorted_into(&o_v, &ws.viol, &mut ws.idx_scratch);
                std::mem::swap(&mut o_v, &mut ws.idx_scratch);
            }

            // Dynamic GAP safe: attempt a post-hoc shrink + resolve cycle
            // emulating every-10-iteration re-screens (exactness means the
            // final answer is unchanged; the win is solver time on smaller
            // designs, measured in fit_seconds).
            if self.rule == RuleKind::GapSafeDyn {
                let dyn_c = crate::screen::gap_safe::screen_dynamic(
                    &pen, &ds.x, &ds.y, &ws.beta_full, lam_next,
                );
                // Workspace-scratch union, like the KKT path above: the
                // violation list and index scratch are both free at this
                // point in the step, so the shrink set costs no
                // allocation after warm-up.
                screen::active_vars_into(&ws.beta_full, &mut ws.viol);
                screen::union_sorted_into(&dyn_c.vars, &ws.viol, &mut ws.idx_scratch);
                if ws.idx_scratch.len() < o_v.len() {
                    ws.beta_warm.copy_from_slice(&ws.beta_full);
                    let warm = std::mem::take(&mut ws.beta_warm);
                    let keep = std::mem::take(&mut ws.idx_scratch);
                    let res = self.solve_on(&pen, kind, &loss, &keep, &warm, lam_next, ws);
                    ws.beta_warm = warm;
                    solver_iterations += res.iterations;
                    status = res.status.worst(status);
                    self.engine.full_gradient_carried(
                        &loss,
                        &ws.beta_full,
                        &ws.xb,
                        &mut ws.r,
                        &mut ws.grad,
                    );
                    o_v.clear();
                    o_v.extend_from_slice(&keep);
                    ws.idx_scratch = keep;
                }
            }

            let a_v = screen::active_vars(&ws.beta_full).len();
            let a_g = screen::active_groups(&ws.beta_full, &pen.groups).len();
            let o_g = {
                let mut gs: Vec<usize> =
                    o_v.iter().map(|&i| pen.groups.group_of(i)).collect();
                gs.dedup();
                gs.len()
            };
            // Final optimality certificate at this λ, from the carried
            // gradient — one O(p) pass, no extra design products.
            let kkt_residual = crate::screen::kkt::stationarity_residual(
                &pen,
                &ws.grad,
                &ws.beta_full,
                lam_next,
            );
            metrics.points.push(PointMetrics {
                lambda: lam_next,
                a_v,
                a_g,
                c_v,
                c_g,
                o_v: o_v.len(),
                o_g,
                kkt_violations,
                kkt_rounds,
                kkt_residual,
                solver_iterations,
                status,
                fit_seconds: t_point.elapsed().as_secs_f64(),
            });
            betas.push(ws.beta_full.clone());
            std::mem::swap(&mut grad_prev, &mut ws.grad);
        }

        metrics.total_seconds = start_total.elapsed().as_secs_f64();
        Ok(PathFit { rule: self.rule, lambdas, betas, metrics })
    }

    /// Solve restricted to `o_v`; leaves the solution scattered to full
    /// length in `ws.beta_full` and its fitted values `Xβ` in `ws.xb`.
    #[allow(clippy::too_many_arguments)]
    fn solve_on(
        &self,
        pen: &Penalty,
        kind: LossKind,
        loss: &Loss,
        o_v: &[usize],
        warm_full: &[f64],
        lam: f64,
        ws: &mut PathWorkspace,
    ) -> SolveResult {
        if self.reference_alloc {
            // Reference semantics at *every* solve — including KKT re-entry
            // rounds and the dynamic re-solve — not just per λ step: cold
            // gather, freshly-allocated solver buffers.
            ws.reduced.invalidate();
            ws.solver = SolverWorkspace::new();
        }
        let p = loss.x.ncols();
        if o_v.len() == p {
            // Full problem — skip the gather.
            let res =
                crate::solver::solve_ws(loss, pen, lam, warm_full, &self.cfg.solver, &mut ws.solver);
            ws.beta_full.copy_from_slice(&res.beta);
            // solve_ws keeps Xβ at the returned iterate in the workspace.
            ws.xb.copy_from_slice(ws.solver.fitted());
            return res;
        }
        let rpen = pen.restrict(o_v);
        ws.warm.clear();
        ws.warm.extend(o_v.iter().map(|&i| warm_full[i]));
        // Grouped gather: the cache records where the gathered columns
        // change original group, so block-coordinate solvers see blocks
        // that tile the reduced design exactly as the restricted
        // penalty's groups do.
        let x_red = ws.reduced.update_grouped(loss.x, o_v, &pen.groups);
        let res = self.engine.solve_reduced(
            kind,
            x_red,
            loss.y,
            &rpen,
            lam,
            &ws.warm,
            &self.cfg.solver,
            &mut ws.solver,
        );
        // Carried fitted values: the reduced fit IS the full-model Xβ
        // (excluded columns contribute nothing). Recomputed from the
        // reduced design (O(n·|O_v|)) so any Engine backend is safe.
        x_red.matvec_par_into(&res.beta, crate::parallel::default_threads(), &mut ws.xb);
        debug_assert_eq!(
            ws.reduced.group_offsets(),
            rpen.groups.offsets(),
            "reduced group-block offsets must tile the reduced design"
        );
        ws.beta_full.fill(0.0);
        for (t, &i) in o_v.iter().enumerate() {
            ws.beta_full[i] = res.beta[t];
        }
        res
    }

    /// Rule-appropriate KKT check over the complement of the optimization
    /// set at the solution currently in `ws` (gradient in `ws.grad`,
    /// coefficients in `ws.beta_full`); fills `ws.viol` (sorted).
    fn kkt_check_into(&self, pen: &Penalty, lam: f64, o_v: &[usize], ws: &mut PathWorkspace) {
        let p = pen.groups.p();
        let PathWorkspace { grad, beta_full, viol, in_ov, group_mask, group_active, .. } = ws;
        for x in in_ov.iter_mut() {
            *x = false;
        }
        for &i in o_v {
            in_ov[i] = true;
        }
        match self.rule {
            RuleKind::Sparsegl => {
                // Group-level: excluded groups are those with NO variable in O_v.
                for x in group_mask.iter_mut() {
                    *x = false;
                }
                for &i in o_v {
                    group_mask[pen.groups.group_of(i)] = true;
                }
                crate::screen::kkt::group_violations_into(
                    pen,
                    grad,
                    lam,
                    (0..pen.groups.m()).filter(|&g| !group_mask[g]),
                    viol,
                );
            }
            _ => crate::screen::kkt::variable_violations_into(
                pen,
                grad,
                beta_full,
                lam,
                (0..p).filter(|&i| !in_ov[i]),
                group_active,
                viol,
            ),
        }
    }
}

/// Convenience: run both a screened and a no-screen fit and report the
/// improvement factor plus the ℓ₂ distance between solutions (the paper's
/// headline comparison for one dataset/rule pair).
pub struct Comparison {
    /// The screened fit (on the no-screen fit's λ path).
    pub screened: PathFit,
    /// The no-screen baseline fit.
    pub no_screen: PathFit,
    /// `no-screen seconds / screened seconds`.
    pub improvement_factor: f64,
    /// Mean per-point ℓ₂ distance between the two solution paths.
    pub l2_distance: f64,
}

/// Run the paired screened / no-screen comparison behind [`Comparison`].
pub fn compare_with_no_screen(
    dataset: &Dataset,
    cfg: &PathConfig,
    rule: RuleKind,
) -> anyhow::Result<Comparison> {
    let no_screen = PathRunner::new(dataset, cfg.clone()).rule(RuleKind::NoScreen).run()?;
    let screened = PathRunner::new(dataset, cfg.clone())
        .rule(rule)
        .fixed_path(no_screen.lambdas.clone())
        .run()?;
    let improvement_factor = crate::metrics::improvement_factor(
        no_screen.metrics.total_seconds,
        screened.metrics.total_seconds,
    );
    let l2_distance = screened.l2_distance_to(&no_screen);
    Ok(Comparison { screened, no_screen, improvement_factor, l2_distance })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticConfig;

    fn small_data() -> crate::data::GeneratedData {
        SyntheticConfig {
            n: 60,
            p: 80,
            groups: crate::data::synthetic::GroupSpec::Even(8),
            ..SyntheticConfig::default()
        }
        .generate(5)
    }

    fn cfg() -> PathConfig {
        // Tight solver tolerance so solution-equality checks measure
        // screening correctness rather than optimizer noise.
        PathConfig {
            path_len: 12,
            solver: crate::solver::SolverConfig {
                tol: 1e-9,
                max_iters: 50_000,
                ..Default::default()
            },
            ..PathConfig::default()
        }
    }

    #[test]
    fn dfr_matches_no_screen_solutions() {
        let gd = small_data();
        let c = compare_with_no_screen(&gd.dataset, &cfg(), RuleKind::DfrSgl).unwrap();
        assert!(
            c.l2_distance < 1e-3,
            "screened solutions drifted: ℓ₂ = {}",
            c.l2_distance
        );
        // Screening must have actually reduced the input.
        assert!(
            c.screened.metrics.input_proportion() < 0.9,
            "input proportion {}",
            c.screened.metrics.input_proportion()
        );
    }

    #[test]
    fn sparsegl_and_gap_safe_match_no_screen() {
        let gd = small_data();
        for rule in [RuleKind::Sparsegl, RuleKind::GapSafeSeq, RuleKind::GapSafeDyn] {
            let c = compare_with_no_screen(&gd.dataset, &cfg(), rule).unwrap();
            assert!(
                c.l2_distance < 1e-3,
                "{}: ℓ₂ distance {}",
                rule.name(),
                c.l2_distance
            );
        }
    }

    #[test]
    fn tlfre_matches_no_screen_with_zero_reentries() {
        let gd = small_data();
        let c = compare_with_no_screen(&gd.dataset, &cfg(), RuleKind::Tlfre).unwrap();
        assert!(c.l2_distance < 1e-3, "TLFre drift {}", c.l2_distance);
        // Safe rule: the no-recheck fast path must record zero KKT events.
        assert_eq!(c.screened.metrics.total_kkt_reentries(), 0);
        assert_eq!(c.screened.metrics.total_kkt_violations(), 0);
        // And it must actually screen.
        assert!(
            c.screened.metrics.input_proportion() < 1.0,
            "TLFre kept everything: O_v/p = {}",
            c.screened.metrics.input_proportion()
        );
    }

    #[test]
    fn asgl_path_runs_and_screens() {
        let gd = small_data();
        let cfg = PathConfig { adaptive: Some((0.1, 0.1)), ..cfg() };
        let c = compare_with_no_screen(&gd.dataset, &cfg, RuleKind::DfrAsgl).unwrap();
        assert!(c.l2_distance < 1e-3, "aSGL drift {}", c.l2_distance);
    }

    #[test]
    fn candidate_sets_nest_dfr_within_sparsegl_groups() {
        // sparsegl keeps whole groups; DFR's optimization set should not be
        // larger on average (Table A3's headline contrast).
        let gd = small_data();
        let dfr = PathRunner::new(&gd.dataset, cfg()).rule(RuleKind::DfrSgl).run().unwrap();
        let spg = PathRunner::new(&gd.dataset, cfg())
            .rule(RuleKind::Sparsegl)
            .fixed_path(dfr.lambdas.clone())
            .run()
            .unwrap();
        assert!(
            dfr.metrics.input_proportion() <= spg.metrics.input_proportion() + 1e-9,
            "DFR {} vs sparsegl {}",
            dfr.metrics.input_proportion(),
            spg.metrics.input_proportion()
        );
    }

    #[test]
    fn logistic_path_runs() {
        let gd = SyntheticConfig {
            n: 80,
            p: 40,
            groups: crate::data::synthetic::GroupSpec::Even(8),
            response: crate::data::Response::Logistic,
            ..SyntheticConfig::default()
        }
        .generate(6);
        let fit = PathRunner::new(&gd.dataset, cfg()).rule(RuleKind::DfrSgl).run().unwrap();
        assert_eq!(fit.betas.len(), 12);
        assert_eq!(fit.metrics.failed_convergences(), 0);
    }

    #[test]
    fn first_path_point_is_null_model() {
        let gd = small_data();
        let fit = PathRunner::new(&gd.dataset, cfg()).rule(RuleKind::DfrSgl).run().unwrap();
        assert!(fit.betas[0].iter().all(|&b| b == 0.0));
        // And something eventually activates along the path.
        assert!(fit.active_vars_last() > 0);
    }

    #[test]
    fn reduced_design_cache_is_exercised_along_the_path() {
        let gd = small_data();
        let mut ws = PathWorkspace::default();
        let fit = PathRunner::new(&gd.dataset, cfg())
            .rule(RuleKind::DfrSgl)
            .run_with_workspace(&mut ws)
            .unwrap();
        assert_eq!(fit.betas.len(), 12);
        // The path must have routed its reduced solves through the cache.
        assert!(
            ws.reduced.hits + ws.reduced.kept_cols + ws.reduced.copied_cols > 0,
            "reduced-design cache never used"
        );
    }

    #[test]
    fn reference_alloc_mode_matches_workspace_mode() {
        let gd = small_data();
        let fast = PathRunner::new(&gd.dataset, cfg()).rule(RuleKind::DfrSgl).run().unwrap();
        let reference = PathRunner::new(&gd.dataset, cfg())
            .rule(RuleKind::DfrSgl)
            .reference_alloc(true)
            .run()
            .unwrap();
        assert!(
            fast.l2_distance_to(&reference) <= 1e-12,
            "workspace reuse changed the path solutions"
        );
    }
}
