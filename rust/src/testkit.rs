//! A miniature property-testing framework (no `proptest` offline).
//!
//! [`check`] runs a property over `cases` randomly generated inputs with a
//! deterministic seed ladder; on failure it reports the failing seed so the
//! case can be replayed exactly. Generators are plain closures over
//! [`crate::rng::Rng`], which keeps shrinking out of scope but makes every
//! failure reproducible from its printed seed.

use crate::rng::Rng;

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with the failing
/// seed on the first violated property.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed on case {case} (replay seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Assert two slices are element-wise close.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}: element {i} differs: {x} vs {y} (tol {tol})"
        );
    }
}

/// Draw a random sparse-group regression problem for property tests.
pub struct RandomProblem {
    pub data: crate::data::GeneratedData,
    pub alpha: f64,
}

impl std::fmt::Debug for RandomProblem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "RandomProblem(p={}, n={}, m={}, alpha={:.2})",
            self.data.dataset.p(),
            self.data.dataset.n(),
            self.data.dataset.m(),
            self.alpha
        )
    }
}

/// Generator for [`RandomProblem`]; bounded sizes keep property suites fast.
pub fn random_problem(rng: &mut Rng) -> RandomProblem {
    let p = 20 + rng.below(40);
    let n = 30 + rng.below(40);
    let group_size = 2 + rng.below(6);
    let cfg = crate::data::SyntheticConfig {
        n,
        p,
        groups: crate::data::synthetic::GroupSpec::Even(group_size),
        group_sparsity: 0.2 + 0.3 * rng.uniform(),
        var_sparsity: 0.2 + 0.4 * rng.uniform(),
        rho: 0.5 * rng.uniform(),
        ..crate::data::SyntheticConfig::default()
    };
    let data = cfg.generate(rng.next_u64());
    let alpha = [0.0, 0.3, 0.5, 0.8, 0.95, 1.0][rng.below(6)];
    RandomProblem { data, alpha }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_valid_property() {
        check("abs-nonneg", 50, |r| r.gauss(), |x| {
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn check_reports_failures() {
        check("always-fails", 3, |r| r.gauss(), |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_tolerates_scale() {
        assert_close(&[1.0, 1e6], &[1.0 + 1e-9, 1e6 + 1.0], 1e-5, "scale");
    }

    #[test]
    fn random_problem_shapes_are_consistent() {
        let mut rng = Rng::new(1);
        for _ in 0..5 {
            let rp = random_problem(&mut rng);
            assert_eq!(rp.data.dataset.groups.p(), rp.data.dataset.p());
        }
    }
}
