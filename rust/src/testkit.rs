//! A miniature property-testing framework (no `proptest` offline).
//!
//! [`check`] runs a property over `cases` randomly generated inputs with a
//! deterministic seed ladder; on failure it reports the failing seed so the
//! case can be replayed exactly. Generators are plain closures over
//! [`crate::rng::Rng`], which keeps shrinking out of scope but makes every
//! failure reproducible from its printed seed.

use crate::rng::Rng;

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with the failing
/// seed on the first violated property.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed on case {case} (replay seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Assert two slices are element-wise close.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}: element {i} differs: {x} vs {y} (tol {tol})"
        );
    }
}

/// Draw a random sparse-group regression problem for property tests.
pub struct RandomProblem {
    pub data: crate::data::GeneratedData,
    pub alpha: f64,
}

impl std::fmt::Debug for RandomProblem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "RandomProblem(p={}, n={}, m={}, alpha={:.2})",
            self.data.dataset.p(),
            self.data.dataset.n(),
            self.data.dataset.m(),
            self.alpha
        )
    }
}

/// Generator for [`RandomProblem`]; bounded sizes keep property suites fast.
pub fn random_problem(rng: &mut Rng) -> RandomProblem {
    let p = 20 + rng.below(40);
    let n = 30 + rng.below(40);
    let group_size = 2 + rng.below(6);
    let cfg = crate::data::SyntheticConfig {
        n,
        p,
        groups: crate::data::synthetic::GroupSpec::Even(group_size),
        group_sparsity: 0.2 + 0.3 * rng.uniform(),
        var_sparsity: 0.2 + 0.4 * rng.uniform(),
        rho: 0.5 * rng.uniform(),
        ..crate::data::SyntheticConfig::default()
    };
    let data = cfg.generate(rng.next_u64());
    let alpha = [0.0, 0.3, 0.5, 0.8, 0.95, 1.0][rng.below(6)];
    RandomProblem { data, alpha }
}

/// One audited λ point: what the coordinator recorded and an independent
/// recomputation of the final stationarity residual.
#[derive(Clone, Debug)]
pub struct KktAuditPoint {
    pub lambda: f64,
    /// KKT violations the coordinator recorded (variables re-entered).
    pub violations: usize,
    /// KKT re-entry rounds the coordinator recorded.
    pub rounds: usize,
    /// Residual recomputed here from scratch (fresh full gradient).
    pub residual: f64,
    /// Residual the coordinator recorded from its carried gradient.
    pub recorded_residual: f64,
}

/// KKT-audit harness: given a finished [`crate::path::PathFit`], rebuild
/// the penalty and recompute the stationarity residual of every path point
/// *independently* of the coordinator (fresh gradients, no carried state),
/// paired with the per-λ violation/re-entry counts the coordinator
/// recorded. [`KktAudit::assert_clean`] is the one-line gate the safety
/// suite runs under every rule: every path point must end KKT-clean, and
/// the recorded residuals must agree with the recomputation.
#[derive(Clone, Debug)]
pub struct KktAudit {
    pub rule: crate::screen::RuleKind,
    pub points: Vec<KktAuditPoint>,
}

impl KktAudit {
    /// Audit `fit` against the dataset/config it was produced from.
    pub fn from_fit(
        dataset: &crate::data::Dataset,
        cfg: &crate::path::PathConfig,
        fit: &crate::path::PathFit,
    ) -> KktAudit {
        use crate::loss::{Loss, LossKind};
        let pen = crate::path::PathRunner::new(dataset, cfg.clone())
            .rule(fit.rule)
            .build_penalty();
        let loss =
            Loss::new(LossKind::for_response(dataset.response), &dataset.x, &dataset.y);
        assert_eq!(
            fit.lambdas.len(),
            fit.metrics.points.len(),
            "malformed fit: λ grid and metrics disagree"
        );
        let points = fit
            .lambdas
            .iter()
            .zip(&fit.betas)
            .zip(&fit.metrics.points)
            .map(|((&lambda, beta), pm)| {
                let grad = loss.gradient(beta);
                let residual =
                    crate::screen::kkt::stationarity_residual(&pen, &grad, beta, lambda);
                KktAuditPoint {
                    lambda,
                    violations: pm.kkt_violations,
                    rounds: pm.kkt_rounds,
                    residual,
                    recorded_residual: pm.kkt_residual,
                }
            })
            .collect();
        KktAudit { rule: fit.rule, points }
    }

    /// Worst independently-recomputed residual along the path.
    pub fn max_residual(&self) -> f64 {
        self.points.iter().fold(0.0f64, |m, pt| m.max(pt.residual))
    }

    /// Total re-entry rounds the coordinator recorded.
    pub fn total_reentries(&self) -> usize {
        self.points.iter().map(|pt| pt.rounds).sum()
    }

    /// Assert every path point ends with a stationarity residual ≤ `tol`
    /// and that the coordinator's recorded residuals match the independent
    /// recomputation. Panics with the offending (rule, λ index) on failure.
    pub fn assert_clean(&self, tol: f64) {
        for (k, pt) in self.points.iter().enumerate() {
            assert!(
                pt.residual <= tol,
                "{}: path point {k} (λ={:.6}) ends KKT-dirty: residual {:.3e} > {tol:.1e}",
                self.rule.name(),
                pt.lambda,
                pt.residual
            );
            assert!(
                (pt.recorded_residual - pt.residual).abs() <= 1e-6 * (1.0 + pt.residual),
                "{}: point {k} recorded residual {:.3e} disagrees with recomputed {:.3e}",
                self.rule.name(),
                pt.recorded_residual,
                pt.residual
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_valid_property() {
        check("abs-nonneg", 50, |r| r.gauss(), |x| {
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn check_reports_failures() {
        check("always-fails", 3, |r| r.gauss(), |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_tolerates_scale() {
        assert_close(&[1.0, 1e6], &[1.0 + 1e-9, 1e6 + 1.0], 1e-5, "scale");
    }

    #[test]
    fn random_problem_shapes_are_consistent() {
        let mut rng = Rng::new(1);
        for _ in 0..5 {
            let rp = random_problem(&mut rng);
            assert_eq!(rp.data.dataset.groups.p(), rp.data.dataset.p());
        }
    }

    /// The audit harness itself: a tightly-solved path must come back clean
    /// under both a strong rule (after its KKT repairs) and a safe rule
    /// (which must additionally record zero re-entry rounds).
    #[test]
    fn kkt_audit_clean_on_small_fits() {
        use crate::path::{PathConfig, PathRunner};
        use crate::screen::RuleKind;
        use crate::solver::SolverConfig;
        let data_cfg = crate::data::SyntheticConfig {
            n: 40,
            p: 24,
            groups: crate::data::synthetic::GroupSpec::Even(6),
            ..crate::data::SyntheticConfig::default()
        };
        let gd = data_cfg.generate(0xA0D17);
        let cfg = PathConfig {
            path_len: 6,
            path_end_ratio: 0.3,
            solver: SolverConfig { tol: 1e-10, max_iters: 100_000, ..Default::default() },
            ..Default::default()
        };
        for rule in [RuleKind::DfrSgl, RuleKind::Tlfre] {
            let fit =
                PathRunner::new(&gd.dataset, cfg.clone()).rule(rule).run().unwrap();
            let audit = KktAudit::from_fit(&gd.dataset, &cfg, &fit);
            assert_eq!(audit.points.len(), cfg.path_len);
            audit.assert_clean(1e-5);
            if !rule.needs_kkt() {
                assert_eq!(audit.total_reentries(), 0, "safe rule recorded re-entries");
                assert!(audit.points.iter().all(|pt| pt.violations == 0));
            }
        }
    }
}
