//! # DFR — Dual Feature Reduction for the Sparse-Group Lasso
//!
//! A production-grade reproduction of *"Dual Feature Reduction for the
//! Sparse-group Lasso and its Adaptive Variant"* (Feser & Evangelou,
//! ICML 2025).
//!
//! ## Module map (→ paper section / equation)
//!
//! | Module | Implements | Paper |
//! |---|---|---|
//! | [`penalty`], [`norms`] | SGL / aSGL norms, ε-norm duals, exact proxes, PCA adaptive weights | Eq. 1–2, §2.1, App. B.3 |
//! | [`solver`] | Solver subsystem behind the [`solver::Solver`] trait: FISTA (exact SGL prox), ATOS, and group-major block-coordinate descent, all warm-started with backtracking | §2.3, App. A (Table A1 settings) |
//! | [`screen`] | DFR bi-level strong rules for SGL (Eqs. 5–6) and aSGL (Eqs. 7–8), `sparsegl` group rule, GAP-safe seq/dyn, no-screen baseline, KKT checks | §2.2, §2.4, App. C |
//! | [`path`] | Algorithm 1/A1: candidates → optimization set → reduced solve → KKT loop; persistent [`path::PathWorkspace`] hot loop | §2.4, App. D.1 metrics |
//! | [`cv`] | Workspace-pooled k-fold CV and `(α, γ)` grid search with shared fold plans, raw-scale fold scoring | §1.2, App. D.7, Table A36 |
//! | [`model_api`] | [`model_api::Design`] input abstraction (dense/row/column/CSC-sparse/out-of-core layouts) + persistent [`model_api::SglFitter`] serving API; CSC designs below the [`model_api::sparse_density_threshold`] solve end-to-end on the centered-implicit sparse kernels ([`linalg::CenteredSparse`]) | — |
//! | [`data`] | Synthetic designs, interaction expansion, surrogate real datasets | §3.1, §4, Table 1, Table A37 |
//! | [`serve`] | Multi-tenant serving: [`serve::FitterPool`] with content-hash-keyed LRU caches shared across tenants ([`lru::KeyedLru`]), round-robin fair admission, coalesced batch prediction, and the `dfr serve` NDJSON loop with live per-verb latency stats | — |
//! | [`metrics`], [`bench_harness`], [`report`] | Improvement factor, input proportion, paper-style tables, `BENCH_*.json` | §3, App. D.1 |
//! | [`linalg`] | Design kernels behind [`linalg::DesignRef`]: dense [`linalg::Matrix`], centered-implicit [`linalg::CenteredSparse`], and chunk-file-streaming [`linalg::OocDesign`] (`dfr pack`, `DFR_OOC_BLOCK`), cache-blocked and row-parallel matvecs on runtime-dispatched compute kernels ([`linalg::kernels`]: scalar / AVX2+FMA / NEON, `DFR_KERNEL`) | — |
//! | [`groups`], [`rng`], [`parallel`], [`cli`], [`testkit`] | Offline substrates (no external crates) | — |
//!
//! ## Quickstart
//!
//! ```no_run
//! use dfr::prelude::*;
//!
//! let data = SyntheticConfig::default().generate(42);
//! let cfg = PathConfig { path_len: 20, ..PathConfig::default() };
//! let fit = PathRunner::new(&data.dataset, cfg)
//!     .rule(RuleKind::DfrSgl)
//!     .run()
//!     .unwrap();
//! println!("selected {} variables at end of path", fit.active_vars_last());
//! ```
//!
//! Serving raw user data — repeated fits, refits, and batch predictions on
//! the same design — goes through a persistent [`model_api::SglFitter`],
//! which caches the standardized dataset (keyed by a content fingerprint
//! of the input [`model_api::Design`]), the path workspaces, and the last
//! pathwise fit:
//!
//! ```no_run
//! use dfr::prelude::*;
//!
//! let rows: Vec<Vec<f64>> = vec![vec![0.0; 8]; 32];
//! let y = vec![0.0; 32];
//! let mut fitter = SglModel::default().fitter();
//! let fit = fitter
//!     .fit_at(&Design::rows(&rows), &y, &[4, 4], Response::Linear, 10)
//!     .unwrap();
//! let sparser = fitter.refit(5).unwrap(); // cached path: no solve at all
//! let mut preds = vec![0.0; 32];
//! fit.predict_into(&Design::rows(&rows), &mut preds); // one matvec
//! # let _ = sparser;
//! ```
//!
//! Joint `(λ, α)` tuning — the workload DFR is built to make cheap — goes
//! through the pooled CV engine:
//!
//! ```no_run
//! use dfr::cv::{CvConfig, CvEngine};
//! use dfr::prelude::*;
//!
//! let data = SyntheticConfig::default().generate(42);
//! let engine = CvEngine::with_default_threads();
//! let cfg = CvConfig { folds: 5, ..CvConfig::default() };
//! let (cells, best) = engine
//!     .grid_search(&data.dataset, &cfg, &[0.5, 0.95], &[None])
//!     .unwrap();
//! println!("winner: α = {}", cells[best].alpha);
//! ```
//!
//! ## Fault tolerance
//!
//! Every solve concludes with a [`solver::SolveStatus`] (not a bare bool):
//! guardrails in the solver driver detect divergence, stalls, and budget
//! exhaustion, a degradation ladder restarts failed solves under FISTA
//! with a halved step, and KKT-cap exhaustion escalates to a certified
//! no-screening solve. Invalid *inputs* are rejected up front with a
//! structured [`error::DfrError`]. The [`faults`] module provides
//! test-only fault-injection hooks (inert unless armed) that the
//! robustness suite uses to prove the pipeline degrades instead of
//! panicking.

// The library proper must not panic through `unwrap`/`expect`: every
// failure is either a structured `DfrError`, an `anyhow` error, or a
// degraded `SolveStatus`. Tests and benches are exempt.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod bench_harness;
pub mod cli;
pub mod cv;
pub mod data;
pub mod error;
pub mod faults;
pub mod groups;
pub mod linalg;
pub mod loss;
pub mod lru;
pub mod metrics;
pub mod model_api;
pub mod norms;
pub mod parallel;
pub mod path;
pub mod penalty;
pub mod report;
pub mod rng;
pub mod screen;
pub mod serve;
pub mod solver;
pub mod testkit;

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::cv::{CvCell, CvConfig, CvEngine, FoldPlan};
    pub use crate::data::real::{RealDatasetKind, SurrogateConfig};
    pub use crate::data::{Dataset, InteractionOrder, Response, SyntheticConfig};
    pub use crate::error::DfrError;
    pub use crate::groups::Groups;
    pub use crate::linalg::{CenteredSparse, CscMatrix, DesignOps, DesignRef, Matrix, OocDesign};
    pub use crate::loss::LossKind;
    pub use crate::lru::KeyedLru;
    pub use crate::metrics::{LatencyHistogram, PathMetrics, PointMetrics};
    pub use crate::model_api::{Design, FittedSgl, SglFitter, SglModel, SparseMode};
    pub use crate::parallel::WorkspacePool;
    pub use crate::path::{PathConfig, PathFit, PathRunner, PathWorkspace};
    pub use crate::solver::SolverWorkspace;
    pub use crate::penalty::{AdaptiveWeights, Penalty};
    pub use crate::rng::Rng;
    pub use crate::screen::RuleKind;
    pub use crate::serve::{FitterPool, PoolConfig, ServeOptions};
    pub use crate::solver::{SolveStatus, SolverConfig, SolverKind};
}
