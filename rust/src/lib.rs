//! # DFR — Dual Feature Reduction for the Sparse-Group Lasso
//!
//! A production-grade reproduction of *"Dual Feature Reduction for the
//! Sparse-group Lasso and its Adaptive Variant"* (Feser & Evangelou,
//! ICML 2025).
//!
//! The crate implements the full pathwise sparse-group-lasso stack:
//!
//! * **Penalties** — SGL and adaptive SGL norms, their ε-norm duals, exact
//!   proximal operators and PCA-based adaptive weights ([`penalty`],
//!   [`norms`]).
//! * **Solvers** — FISTA with the exact SGL prox and ATOS (adaptive
//!   three-operator splitting, the paper's solver), both warm-started with
//!   backtracking line search ([`solver`]).
//! * **Screening** — the paper's contribution: DFR bi-level strong rules for
//!   SGL (Eqs. 5–6) and aSGL (Eqs. 7–8), the `sparsegl` group-only strong
//!   rule, GAP-safe sequential/dynamic exact rules, and a no-screen
//!   baseline, all behind one [`screen::ScreenRule`] interface with
//!   KKT-violation checking ([`screen`]).
//! * **Pathwise coordinator** — Algorithm 1/A1: candidate sets →
//!   optimization set → reduced solve → KKT loop, with full per-path-point
//!   metrics capture ([`path`]).
//! * **Runtime** — PJRT execution of AOT-compiled JAX/Pallas artifacts
//!   (HLO text) for the dense hot path; Python never runs at fit time
//!   ([`runtime`]).
//! * **Substrates** — dense linear algebra, RNG, synthetic + surrogate-real
//!   data generators, k-fold CV, a bench harness and a property-testing kit
//!   (no external crates are available offline).
//!
//! ## Quickstart
//!
//! ```no_run
//! use dfr::prelude::*;
//!
//! let data = SyntheticConfig::default().generate(42);
//! let cfg = PathConfig { path_len: 20, ..PathConfig::default() };
//! let fit = PathRunner::new(&data.dataset, cfg)
//!     .rule(RuleKind::DfrSgl)
//!     .run()
//!     .unwrap();
//! println!("selected {} variables at end of path", fit.active_vars_last());
//! ```

pub mod bench_harness;
pub mod cli;
pub mod cv;
pub mod data;
pub mod groups;
pub mod linalg;
pub mod loss;
pub mod metrics;
pub mod model_api;
pub mod norms;
pub mod parallel;
pub mod path;
pub mod penalty;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod screen;
pub mod solver;
pub mod testkit;

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::data::real::{RealDatasetKind, SurrogateConfig};
    pub use crate::data::{Dataset, InteractionOrder, Response, SyntheticConfig};
    pub use crate::groups::Groups;
    pub use crate::linalg::Matrix;
    pub use crate::loss::LossKind;
    pub use crate::metrics::{PathMetrics, PointMetrics};
    pub use crate::model_api::{FittedSgl, SglModel};
    pub use crate::path::{PathConfig, PathFit, PathRunner, PathWorkspace};
    pub use crate::solver::SolverWorkspace;
    pub use crate::penalty::{AdaptiveWeights, Penalty};
    pub use crate::rng::Rng;
    pub use crate::screen::RuleKind;
    pub use crate::solver::{SolverConfig, SolverKind};
}
