//! Fault injection for robustness testing (the `testkit` companion).
//!
//! The solve pipeline carries a handful of *fault hooks* — in the loss
//! residual, the solvers' backtracking loops, and the [`crate::solver`]
//! iteration driver — that are inert in production: each hook is a single
//! relaxed atomic load when no fault plan is armed. Tests arm a
//! [`FaultPlan`] with [`with_plan`] to force the failure modes the
//! guardrails must catch:
//!
//! * a NaN poisoned into the gradient residual after a countdown,
//! * backtracking that never certifies for one [`SolverKind`],
//! * a truncated iteration budget (caps `max_iters` from outside).
//!
//! Plans are **thread-local**: a plan armed on a test thread fires only in
//! solves running on that thread, so concurrent tests (and `par_map`
//! worker threads) are unaffected. The global armed counter exists purely
//! so the disarmed fast path costs one atomic load and no TLS access.
//!
//! This module is test infrastructure, like [`crate::testkit`]; nothing in
//! the library arms a plan on its own.

use crate::solver::SolverKind;
use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Count of threads with an armed plan (fast-path gate for every hook).
static ACTIVE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static PLAN: RefCell<Option<FaultPlan>> = const { RefCell::new(None) };
}

/// What to break, and when. All fields independent; `None` = inert.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Poison the gradient residual with a NaN after this many
    /// [`crate::loss::Loss::residual_from_xb`] calls (0 = the next one).
    /// Fires once, then disarms itself.
    pub nan_gradient_after: Option<u32>,
    /// Force the named solver's backtracking bound check to fail on every
    /// attempt, exhausting `max_backtrack` (other solvers untouched — a
    /// FISTA fallback after a forced BCD failure must be able to succeed).
    pub fail_backtrack_for: Option<SolverKind>,
    /// Cap every solve's iteration budget below `cfg.max_iters`.
    pub truncate_iters: Option<usize>,
}

/// Arm `plan` on the current thread for the duration of `f`, then disarm
/// (also on panic — the guard is drop-based, so a failing assertion in a
/// property test cannot leak the plan into later tests on this thread).
pub fn with_plan<T>(plan: FaultPlan, f: impl FnOnce() -> T) -> T {
    struct Disarm;
    impl Drop for Disarm {
        fn drop(&mut self) {
            PLAN.with(|p| *p.borrow_mut() = None);
            ACTIVE.fetch_sub(1, Ordering::SeqCst);
        }
    }
    PLAN.with(|p| *p.borrow_mut() = Some(plan));
    ACTIVE.fetch_add(1, Ordering::SeqCst);
    let _guard = Disarm;
    f()
}

#[inline]
fn armed() -> bool {
    ACTIVE.load(Ordering::Relaxed) > 0
}

/// Hook: called by [`crate::loss::Loss::residual_from_xb`] after filling
/// `out`; poisons the first entry with NaN when the countdown fires.
/// Returns whether it mutated the buffer, so fused residual-sum callers
/// know their carried `Σᵢ rᵢ` is stale and must be recomputed.
#[inline]
pub(crate) fn poison_residual(out: &mut [f64]) -> bool {
    if !armed() {
        return false;
    }
    PLAN.with(|p| {
        let mut guard = p.borrow_mut();
        let Some(plan) = guard.as_mut() else {
            return false;
        };
        match plan.nan_gradient_after {
            Some(0) => {
                plan.nan_gradient_after = None;
                if let Some(v) = out.first_mut() {
                    *v = f64::NAN;
                    true
                } else {
                    false
                }
            }
            Some(k) => {
                plan.nan_gradient_after = Some(k - 1);
                false
            }
            None => false,
        }
    })
}

/// Hook: called inside a solver's backtracking bound check; `true` forces
/// the bound to be treated as violated for the named solver.
#[inline]
pub(crate) fn backtrack_must_fail(kind: SolverKind) -> bool {
    if !armed() {
        return false;
    }
    PLAN.with(|p| {
        p.borrow().as_ref().map(|plan| plan.fail_backtrack_for == Some(kind)).unwrap_or(false)
    })
}

/// Hook: called once per solve by the iteration driver; caps the budget.
#[inline]
pub(crate) fn iteration_cap() -> Option<usize> {
    if !armed() {
        return None;
    }
    PLAN.with(|p| p.borrow().as_ref().and_then(|plan| plan.truncate_iters))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hooks_are_inert_without_a_plan() {
        let mut r = [1.0, 2.0];
        poison_residual(&mut r);
        assert_eq!(r, [1.0, 2.0]);
        assert!(!backtrack_must_fail(SolverKind::Fista));
        assert_eq!(iteration_cap(), None);
    }

    #[test]
    fn nan_countdown_fires_once() {
        with_plan(
            FaultPlan { nan_gradient_after: Some(1), ..FaultPlan::default() },
            || {
                let mut r = [1.0, 2.0];
                poison_residual(&mut r); // countdown 1 → 0
                assert!(r[0].is_finite());
                poison_residual(&mut r); // fires
                assert!(r[0].is_nan());
                r[0] = 5.0;
                poison_residual(&mut r); // disarmed
                assert_eq!(r[0], 5.0);
            },
        );
    }

    #[test]
    fn backtrack_failure_is_per_kind() {
        with_plan(
            FaultPlan { fail_backtrack_for: Some(SolverKind::Bcd), ..FaultPlan::default() },
            || {
                assert!(backtrack_must_fail(SolverKind::Bcd));
                assert!(!backtrack_must_fail(SolverKind::Fista));
            },
        );
    }

    #[test]
    fn plan_disarms_on_exit_even_on_panic() {
        let caught = std::panic::catch_unwind(|| {
            with_plan(
                FaultPlan { truncate_iters: Some(3), ..FaultPlan::default() },
                || {
                    assert_eq!(iteration_cap(), Some(3));
                    panic!("boom");
                },
            )
        });
        assert!(caught.is_err());
        assert_eq!(iteration_cap(), None);
    }
}
