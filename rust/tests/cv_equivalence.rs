//! CV-engine equivalence properties: the workspace-pooled, shared-split,
//! grid-flattened engine must return results numerically identical
//! (ℓ₂ ≤ 1e-10) to the per-cell fresh-allocation reference — same
//! `cv_loss` curves, same `best_idx` per cell, same winning cell — for
//! both DFR-SGL and the adaptive variant.

use dfr::cv::{grid_search_reference, CvConfig, CvEngine, FoldPlan};
use dfr::data::SyntheticConfig;
use dfr::path::{PathConfig, PathRunner};
use dfr::screen::RuleKind;
use dfr::solver::SolverConfig;

fn data(seed: u64) -> dfr::data::Dataset {
    SyntheticConfig {
        n: 60,
        p: 40,
        groups: dfr::data::synthetic::GroupSpec::Even(8),
        ..SyntheticConfig::default()
    }
    .generate(seed)
    .dataset
}

fn cfg(rule: RuleKind) -> CvConfig {
    CvConfig {
        folds: 3,
        path: PathConfig {
            path_len: 8,
            solver: SolverConfig { tol: 1e-8, max_iters: 20_000, ..Default::default() },
            ..PathConfig::default()
        },
        rule,
        seed: 11,
        threads: 2,
    }
}

fn l2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

fn assert_grids_match(
    ds: &dfr::data::Dataset,
    base: &CvConfig,
    alphas: &[f64],
    gammas: &[Option<(f64, f64)>],
) {
    let engine = CvEngine::new(base.threads);
    let (pooled, best_pooled) = engine.grid_search(ds, base, alphas, gammas).unwrap();
    let (reference, best_ref) = grid_search_reference(ds, base, alphas, gammas).unwrap();
    assert_eq!(pooled.len(), reference.len());
    assert_eq!(best_pooled, best_ref, "pooled engine picked a different winner");
    for (i, (a, b)) in pooled.iter().zip(&reference).enumerate() {
        assert_eq!(a.alpha, b.alpha, "cell {i} α mismatch");
        assert_eq!(a.gamma, b.gamma, "cell {i} γ mismatch");
        assert_eq!(a.best_idx, b.best_idx, "cell {i} best_idx drifted");
        assert_eq!(a.best_1se_idx, b.best_1se_idx, "cell {i} 1-SE index drifted");
        let d_loss = l2(&a.cv_loss, &b.cv_loss);
        assert!(d_loss <= 1e-10, "cell {i} cv_loss drift ℓ₂ = {d_loss}");
        let d_se = l2(&a.cv_se, &b.cv_se);
        assert!(d_se <= 1e-10, "cell {i} cv_se drift ℓ₂ = {d_se}");
        let d_lam = l2(&a.lambdas, &b.lambdas);
        assert!(d_lam <= 1e-10, "cell {i} λ grid drift ℓ₂ = {d_lam}");
    }
}

/// Pooled grid search over α matches the reference for DFR-SGL.
#[test]
fn pooled_grid_matches_reference_for_dfr_sgl() {
    let ds = data(21);
    assert_grids_match(&ds, &cfg(RuleKind::DfrSgl), &[0.5, 0.95], &[None]);
}

/// Pooled joint (α × γ) grid matches the reference for the adaptive
/// variant — exercising the shared per-(γ, fold) adaptive weights.
#[test]
fn pooled_grid_matches_reference_for_asgl() {
    let ds = data(22);
    assert_grids_match(
        &ds,
        &cfg(RuleKind::DfrAsgl),
        &[0.95],
        &[Some((0.1, 0.1)), Some((0.5, 0.5))],
    );
}

/// A mixed grid (plain + adaptive cells) under a rule that only adapts
/// when γ is given: both γ kinds coexist in one flattened schedule.
#[test]
fn pooled_grid_matches_reference_on_mixed_gamma_grid() {
    let ds = data(23);
    assert_grids_match(&ds, &cfg(RuleKind::DfrSgl), &[0.9], &[None, Some((0.2, 0.2))]);
}

/// The pooled engine's held-out losses equal a hand-computed raw-scale
/// fold error: fit each fold serially, map its coefficients back through
/// the fold's standardization (β_raw = β/s, intercept = ȳ_train − Σβm/s),
/// and score the untouched parent-scale test rows. Pins the ROADMAP
/// refinement that CV scoring unstandardizes per fold rather than
/// evaluating fold-scale β against parent-scale rows.
#[test]
fn pooled_cv_loss_equals_hand_computed_raw_scale_fold_error() {
    // A deliberately unstandardized parent (offset + per-column scale), so
    // the raw-scale mapping actually has work to do.
    let mut ds = data(25);
    for j in 0..ds.p() {
        let scale = 1.0 + j as f64 / 3.0;
        for i in 0..ds.n() {
            let v = ds.x.dense().get(i, j);
            ds.x.dense_mut().set(i, j, 4.0 + scale * v);
        }
    }
    let base = cfg(RuleKind::DfrSgl);
    let engine = CvEngine::new(base.threads);
    let cell = engine.cross_validate(&ds, &base).unwrap();

    // Hand-computed: same fold plan, serial per-fold path fits on the
    // cell's λ grid, manual unstandardization, manual MSE on raw rows.
    let plan = FoldPlan::new(&ds, base.folds, base.seed).unwrap();
    let mut want = vec![0.0; cell.lambdas.len()];
    for fold in &plan.folds {
        let fit = PathRunner::new(&fold.train, base.path.clone())
            .rule(base.rule)
            .fixed_path(cell.lambdas.clone())
            .run()
            .unwrap();
        for (l, beta_std) in fit.betas.iter().enumerate() {
            let mut shift = 0.0;
            let beta_raw: Vec<f64> = beta_std
                .iter()
                .zip(&fold.centers)
                .map(|(&b, &(m, s))| {
                    shift += b * m / s;
                    b / s
                })
                .collect();
            let intercept = fold.train_y_mean - shift;
            let mut mse = 0.0;
            for i in 0..fold.test.n() {
                let eta: f64 = intercept
                    + (0..fold.test.p())
                        .map(|j| fold.test.x.dense().get(i, j) * beta_raw[j])
                        .sum::<f64>();
                mse += (fold.test.y[i] - eta) * (fold.test.y[i] - eta);
            }
            want[l] += mse / fold.test.n() as f64 / plan.folds.len() as f64;
        }
    }
    let d = l2(&cell.cv_loss, &want);
    assert!(d <= 1e-10, "pooled CV loss vs hand-computed raw-scale error: ℓ₂ = {d}");
    // Sanity: losses are finite and the λ grid is the full-data one.
    assert!(cell.cv_loss.iter().all(|v| v.is_finite()));
    assert_eq!(cell.lambdas.len(), base.path.path_len);
}

/// Warm pools are not just consistent run-to-run but identical to the
/// reference: re-running on an already-grown pool changes nothing.
#[test]
fn warm_pool_rerun_stays_equivalent() {
    let ds = data(24);
    let base = cfg(RuleKind::DfrSgl);
    let alphas = [0.5, 0.95];
    let engine = CvEngine::new(2);
    let (first, _) = engine.grid_search(&ds, &base, &alphas, &[None]).unwrap();
    let (second, _) = engine.grid_search(&ds, &base, &alphas, &[None]).unwrap();
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.best_idx, b.best_idx);
        assert_eq!(a.cv_loss, b.cv_loss, "warm pool rerun drifted");
    }
    assert_eq!(engine.pool_slots(), 2, "pool grew across invocations");
    // 2 runs × 2 cells × (1 reference fit + 3 fold fits) = 16 checkouts.
    assert_eq!(engine.pool_checkouts(), 16);
}
