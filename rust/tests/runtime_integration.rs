//! Integration tests across the three layers: AOT artifacts (Pallas + JAX,
//! built by `make artifacts`) loaded and executed through the PJRT runtime,
//! checked against the native Rust engine.
//!
//! Tests self-skip (with a loud message) when `artifacts/` has not been
//! built, so `cargo test` stays green in a fresh checkout; `make test`
//! always builds artifacts first.

use dfr::data::{Response, SyntheticConfig};
use dfr::linalg::Matrix;
use dfr::loss::{Loss, LossKind};
use dfr::path::{Engine, PathConfig, PathRunner};
use dfr::rng::Rng;
use dfr::runtime::XlaEngine;
use dfr::screen::RuleKind;

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/.stamp").exists() {
        Some("artifacts")
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// The smoke shape (32×64) artifact computes the same gradient as native.
#[test]
fn xla_gradient_matches_native_squared() {
    let Some(dir) = artifacts_dir() else { return };
    let eng = XlaEngine::new(dir).unwrap();
    let mut rng = Rng::new(1);
    let x = Matrix::from_fn(32, 64, |_, _| rng.gauss());
    let y: Vec<f64> = rng.gauss_vec(32);
    let loss = Loss::new(LossKind::Squared, &x, &y);
    for trial in 0..5 {
        let beta: Vec<f64> = rng.gauss_vec(64);
        let g_xla = eng.gradient_via_xla(LossKind::Squared, &x, &y, &beta).unwrap();
        let g_nat = loss.gradient(&beta);
        dfr::testkit::assert_close(&g_xla, &g_nat, 1e-10, &format!("trial {trial}"));
    }
    let stats = eng.stats();
    assert_eq!(stats.xla_gradient_calls, 5);
    assert_eq!(stats.native_fallbacks, 0);
    assert_eq!(stats.compiled_artifacts, 1, "executable should be cached");
}

#[test]
fn xla_gradient_matches_native_logistic() {
    let Some(dir) = artifacts_dir() else { return };
    let eng = XlaEngine::new(dir).unwrap();
    let mut rng = Rng::new(2);
    let x = Matrix::from_fn(32, 64, |_, _| rng.gauss());
    let y: Vec<f64> = (0..32).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
    let loss = Loss::new(LossKind::Logistic, &x, &y);
    let beta: Vec<f64> = rng.gauss_vec(64).iter().map(|v| 0.2 * v).collect();
    let g_xla = eng.gradient_via_xla(LossKind::Logistic, &x, &y, &beta).unwrap();
    let g_nat = loss.gradient(&beta);
    dfr::testkit::assert_close(&g_xla, &g_nat, 1e-10, "logistic");
}

/// Full pathwise DFR fit with the XLA engine serving every screening/KKT
/// gradient: solutions must match the native-engine fit exactly (same λ
/// path, same screening decisions).
#[test]
fn pathwise_fit_via_xla_engine_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let gd = SyntheticConfig {
        n: 32,
        p: 64,
        groups: dfr::data::synthetic::GroupSpec::Even(8),
        ..SyntheticConfig::default()
    }
    .generate(7);
    let cfg = PathConfig {
        path_len: 8,
        solver: dfr::solver::SolverConfig { tol: 1e-9, max_iters: 50_000, ..Default::default() },
        ..PathConfig::default()
    };
    let native = PathRunner::new(&gd.dataset, cfg.clone()).rule(RuleKind::DfrSgl).run().unwrap();
    let eng = XlaEngine::new(dir).unwrap();
    let xla = PathRunner::new(&gd.dataset, cfg)
        .rule(RuleKind::DfrSgl)
        .engine(&eng)
        .run()
        .unwrap();
    assert!(eng.stats().xla_gradient_calls > 0, "XLA engine was never used");
    assert_eq!(eng.stats().native_fallbacks, 0, "unexpected fallbacks");
    let dist = xla.l2_distance_to(&native);
    assert!(dist < 1e-5, "engines disagree: ℓ₂ = {dist}");
}

/// Logistic pathwise fit through the XLA engine.
#[test]
fn logistic_pathwise_fit_via_xla_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let gd = SyntheticConfig {
        n: 32,
        p: 64,
        groups: dfr::data::synthetic::GroupSpec::Even(8),
        response: Response::Logistic,
        ..SyntheticConfig::default()
    }
    .generate(8);
    let eng = XlaEngine::new(dir).unwrap();
    let cfg = PathConfig { path_len: 6, ..PathConfig::default() };
    let fit = PathRunner::new(&gd.dataset, cfg)
        .rule(RuleKind::DfrSgl)
        .engine(&eng)
        .run()
        .unwrap();
    assert_eq!(fit.metrics.failed_convergences(), 0);
    assert!(eng.stats().xla_gradient_calls > 0);
}

/// The bucketed AOT FISTA chunks reach the same solution as the native
/// solver on a screened-size reduced problem.
#[test]
fn xla_fista_chunks_match_native_solver() {
    let Some(dir) = artifacts_dir() else { return };
    let eng = XlaEngine::new(dir).unwrap();
    let mut rng = Rng::new(4);
    let n = 200;
    for k in [10usize, 33, 60, 120] {
        let mut x = Matrix::from_fn(n, k, |_, _| rng.gauss());
        x.standardize_l2();
        let y: Vec<f64> = rng.gauss_vec(n);
        let groups = dfr::groups::Groups::even(k, 5);
        let pen = dfr::penalty::Penalty::sgl(groups, 0.9);
        let all: Vec<usize> = (0..k).collect();
        let rpen = pen.restrict(&all);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let lam_max =
            dfr::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; k]), &pen.groups, 0.9);
        let lam = 0.3 * lam_max;
        let cfg = dfr::solver::SolverConfig { tol: 1e-10, max_iters: 50_000, ..Default::default() };
        let native = dfr::solver::solve(&loss, &rpen, lam, &vec![0.0; k], &cfg);
        let via_xla = eng
            .solve_reduced_via_xla(&x, &y, &rpen, lam, &vec![0.0; k], &cfg)
            .unwrap();
        assert!(via_xla.converged(), "k={k}: xla solve did not converge");
        assert!(
            (via_xla.objective - native.objective).abs() < 1e-7 * (1.0 + native.objective),
            "k={k}: objective {} vs native {}",
            via_xla.objective,
            native.objective
        );
        dfr::testkit::assert_close(&via_xla.beta, &native.beta, 1e-4, &format!("k={k} beta"));
    }
    assert!(eng.stats().xla_solver_chunks > 0);
}

/// A full pathwise DFR fit with BOTH the gradient and the inner solver
/// served by PJRT — the complete three-layer hot path.
#[test]
fn full_path_with_xla_solver_and_gradient() {
    let Some(dir) = artifacts_dir() else { return };
    let gd = SyntheticConfig {
        n: 200,
        p: 1000,
        ..SyntheticConfig::default()
    }
    .generate(11);
    let cfg = PathConfig {
        path_len: 10,
        solver: dfr::solver::SolverConfig { tol: 1e-8, max_iters: 20_000, ..Default::default() },
        ..PathConfig::default()
    };
    let native = PathRunner::new(&gd.dataset, cfg.clone()).rule(RuleKind::DfrSgl).run().unwrap();
    let eng = XlaEngine::new(dir).unwrap();
    let xla = PathRunner::new(&gd.dataset, cfg)
        .rule(RuleKind::DfrSgl)
        .engine(&eng)
        .fixed_path(native.lambdas.clone())
        .run()
        .unwrap();
    let stats = eng.stats();
    assert!(stats.xla_gradient_calls > 0, "gradients not served by PJRT");
    assert!(stats.xla_solver_chunks > 0, "solver not served by PJRT");
    let dist = xla.l2_distance_to(&native);
    assert!(dist < 1e-4, "full-XLA path drifted: ℓ₂ = {dist}");
}

/// Regression: one engine reused across two *different* datasets of the
/// same shape must not serve a stale device buffer. (The device cache was
/// originally keyed by host pointer + length alone; an allocator reusing a
/// dropped dataset's memory aliased the cache — caught because a bench
/// rep produced wholesale-wrong solutions.)
#[test]
fn engine_reuse_across_datasets_does_not_alias_buffers() {
    let Some(dir) = artifacts_dir() else { return };
    let eng = XlaEngine::new(dir).unwrap();
    let beta = vec![0.25; 64];
    for seed in 0..6 {
        // Fresh allocation each round; drop the previous one first so the
        // allocator is free to hand back the same address.
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(32, 64, |_, _| rng.gauss());
        let y: Vec<f64> = rng.gauss_vec(32);
        let loss = Loss::new(LossKind::Squared, &x, &y);
        let g_xla = eng.gradient_via_xla(LossKind::Squared, &x, &y, &beta).unwrap();
        let g_nat = loss.gradient(&beta);
        dfr::testkit::assert_close(&g_xla, &g_nat, 1e-10, &format!("seed {seed}"));
    }
}

/// Shape misses must fall back to native without corrupting results.
#[test]
fn unmatched_shape_falls_back() {
    let Some(dir) = artifacts_dir() else { return };
    let eng = XlaEngine::new(dir).unwrap();
    let mut rng = Rng::new(3);
    let x = Matrix::from_fn(17, 23, |_, _| rng.gauss()); // no artifact for 17x23
    let y: Vec<f64> = rng.gauss_vec(17);
    let loss = Loss::new(LossKind::Squared, &x, &y);
    let beta = vec![0.3; 23];
    let g = eng.full_gradient(&loss, &beta);
    dfr::testkit::assert_close(&g, &loss.gradient(&beta), 1e-12, "fallback");
    assert_eq!(eng.stats().native_fallbacks, 1);
}
