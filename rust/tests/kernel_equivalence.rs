//! Kernel-backend equivalence gates (the dispatch layer's safety net).
//!
//! Three contracts, each pinned here:
//!
//! 1. **Bit stability** — with the backend pinned to `scalar` (what
//!    `DFR_KERNEL=scalar` resolves to; the env var itself is read once at
//!    process start, so the tests pin through the programmatic override,
//!    which takes the same dispatch path), every design kernel reproduces
//!    the historical pre-dispatch implementations bit for bit.
//! 2. **Dispatched accuracy** — the auto-selected backend (AVX2+FMA where
//!    available) matches the scalar reference within `1e-12`-scale ℓ₂ on
//!    randomized shapes: odd lengths, SIMD remainder lanes, all-zero
//!    columns, zero coefficients, empty blocks.
//! 3. **Chunking transparency** — parallel/blocked forms agree with their
//!    serial counterparts: exactly where the kernel structure guarantees
//!    it (column-chunked `Xᵀr`, sparse row-partitioned `X̃β`, carried
//!    residual sums), within tolerance where SIMD lane alignment may
//!    legitimately shift (dense row-chunked `Xβ` on the AVX2 backend).
//!
//! Tests that flip the process-global backend or `DFR_PAR_GRAIN` override
//! serialize on one mutex and restore the defaults through a drop guard,
//! so a failing assertion cannot leak a pinned backend into other tests.

use dfr::linalg::kernels::{self, Backend};
use dfr::linalg::{CenteredSparse, CscMatrix, Matrix};
use dfr::rng::Rng;
use std::sync::{Mutex, MutexGuard};

static LOCK: Mutex<()> = Mutex::new(());

/// Serialize the test and pin the dispatched backend; restores auto
/// selection (and the parallel grain default) on drop, panics included.
struct Pin {
    _guard: MutexGuard<'static, ()>,
}

fn pin(b: Option<Backend>) -> Pin {
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    kernels::set_backend_override(b);
    Pin { _guard: guard }
}

impl Drop for Pin {
    fn drop(&mut self) {
        kernels::set_backend_override(None);
        dfr::parallel::set_par_grain_override(None);
    }
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

fn assert_close(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let (mut dsq, mut nsq) = (0.0, 0.0);
    for (x, y) in a.iter().zip(b) {
        dsq += (x - y) * (x - y);
        nsq += y * y;
    }
    let tol = 1e-12 * (1.0 + nsq.sqrt());
    assert!(dsq.sqrt() <= tol, "{what}: ℓ₂ distance {} > {tol}", dsq.sqrt());
}

/// Random design with all-zero columns (every 5th) and a coefficient
/// vector with exact zeros (every 4th) — the skip paths the blocked
/// kernels special-case.
fn dense_design(n: usize, p: usize, seed: u64) -> (Matrix, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Matrix::from_fn(n, p, |_, j| if j % 5 == 3 { 0.0 } else { rng.gauss() });
    let r = rng.gauss_vec(n);
    let beta: Vec<f64> = rng
        .gauss_vec(p)
        .iter()
        .enumerate()
        .map(|(j, v)| if j % 4 == 1 { 0.0 } else { *v })
        .collect();
    (x, r, beta)
}

fn sparse_design(n: usize, p: usize, seed: u64) -> CenteredSparse {
    let mut rng = Rng::new(seed);
    let xd = Matrix::from_fn(n, p, |_, j| {
        if j % 6 == 5 || !rng.bernoulli(0.3) {
            0.0
        } else {
            rng.gauss()
        }
    });
    CenteredSparse::from_csc(&CscMatrix::from_dense(&xd, 0.0))
}

/// The shapes every gate sweeps: degenerate, sub-lane, one-past-lane,
/// odd primes (SIMD remainders on both the 4-wide register blocks and the
/// 4-lane vector loops), and a few square-ish sizes.
const SHAPES: [(usize, usize); 8] =
    [(1, 1), (2, 3), (5, 4), (7, 9), (17, 8), (64, 16), (103, 37), (250, 33)];

// --- contract 1: DFR_KERNEL=scalar is bit-stable ------------------------

/// The historical 4-accumulator dot, copied verbatim as an independent
/// reference (if `kernels::scalar` drifts, this fails).
fn ref_dot(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// The historical dense `Xβ`: skip-zero column axpys in index order.
fn ref_matvec(x: &Matrix, beta: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; x.nrows()];
    for (j, &b) in beta.iter().enumerate() {
        if b != 0.0 {
            for (o, &v) in out.iter_mut().zip(x.col(j)) {
                *o += b * v;
            }
        }
    }
    out
}

#[test]
fn pinned_scalar_backend_reproduces_the_historical_kernels_bitwise() {
    let _pin = pin(Some(Backend::Scalar));
    assert_eq!(kernels::active(), Backend::Scalar);
    for (si, &(n, p)) in SHAPES.iter().enumerate() {
        let (x, r, beta) = dense_design(n, p, 500 + si as u64);
        let what = format!("scalar pin {n}x{p}");

        let mut xb = vec![0.0; n];
        x.matvec_into(&beta, &mut xb);
        assert_bits_eq(&xb, &ref_matvec(&x, &beta), &format!("{what} matvec"));

        let mut g = vec![0.0; p];
        x.t_matvec_into(&r, &mut g);
        let ref_g: Vec<f64> = (0..p).map(|j| ref_dot(x.col(j), &r)).collect();
        assert_bits_eq(&g, &ref_g, &format!("{what} t_matvec"));

        let mut sq = vec![0.0; p];
        x.col_sq_norms_into(&mut sq);
        let ref_sq: Vec<f64> = (0..p).map(|j| ref_dot(x.col(j), x.col(j))).collect();
        assert_bits_eq(&sq, &ref_sq, &format!("{what} col_sq_norms"));

        // Free-function vector kernels route through the same dispatch.
        assert_eq!(dfr::linalg::dot(&r, &r).to_bits(), ref_dot(&r, &r).to_bits(), "{what} dot");
    }
}

// --- contract 2: dispatched ≡ scalar within tolerance -------------------

#[test]
fn dispatched_backend_matches_scalar_on_randomized_shapes() {
    let _pin = pin(None);
    for (si, &(n, p)) in SHAPES.iter().enumerate() {
        let (x, r, beta) = dense_design(n, p, 900 + si as u64);
        let what = format!("dispatched {n}x{p}");

        // Scalar references through the explicit-backend entry points
        // (no override flip mid-test).
        let want_xb = ref_matvec(&x, &beta);
        let want_g: Vec<f64> = (0..p).map(|j| ref_dot(x.col(j), &r)).collect();

        let mut xb = vec![0.0; n];
        x.matvec_into(&beta, &mut xb);
        assert_close(&xb, &want_xb, &format!("{what} matvec"));

        let mut g = vec![0.0; p];
        x.t_matvec_into(&r, &mut g);
        assert_close(&g, &want_g, &format!("{what} t_matvec"));

        // Block kernels over an interior window (plus the empty block).
        let cols = (p / 3)..(p - p / 4).max(p / 3);
        let mut blk = vec![0.0; cols.len()];
        x.block_t_matvec_into(cols.clone(), &r, &mut blk);
        assert_close(&blk, &want_g[cols.clone()], &format!("{what} block_t_matvec"));

        let mut acc = r.clone();
        x.block_axpy_into(cols.clone(), &beta[cols.clone()], &mut acc);
        let mut want_acc = r.clone();
        for (k, &b) in beta[cols.clone()].iter().enumerate() {
            if b != 0.0 {
                for (o, &v) in want_acc.iter_mut().zip(x.col(cols.start + k)) {
                    *o += b * v;
                }
            }
        }
        assert_close(&acc, &want_acc, &format!("{what} block_axpy"));

        let mut empty: [f64; 0] = [];
        x.block_t_matvec_into(0..0, &r, &mut empty);
        x.block_axpy_into(0..0, &[], &mut acc);
        assert_close(&acc, &want_acc, &format!("{what} empty block_axpy is a no-op"));

        let mut sq = vec![0.0; p];
        x.col_sq_norms_into(&mut sq);
        let want_sq: Vec<f64> = (0..p).map(|j| ref_dot(x.col(j), x.col(j))).collect();
        assert_close(&sq, &want_sq, &format!("{what} col_sq_norms"));
    }
}

#[test]
fn unavailable_backend_requests_degrade_to_a_runnable_one() {
    let _pin = pin(Some(Backend::Avx2));
    let active = kernels::active();
    assert!(active.is_available(), "active backend {active:?} must be runnable");
    if !Backend::Avx2.is_available() {
        assert_eq!(active, Backend::Scalar, "unavailable pin must clamp to scalar");
    }
    assert_eq!(kernels::parse_choice("scalar"), Ok(Some(Backend::Scalar)));
    // NEON parses on every arch; the pin clamps to scalar off-aarch64.
    assert_eq!(kernels::parse_choice("neon"), Ok(Some(Backend::Neon)));
    assert!(kernels::parse_choice("avx512").is_err());
}

// --- contract 3: chunking transparency ----------------------------------

#[test]
fn parallel_and_carried_sum_forms_match_serial() {
    for pin_choice in [Some(Backend::Scalar), None] {
        let _pin = pin(pin_choice);
        // Grain 1 forces the parallel paths even at test sizes.
        dfr::parallel::set_par_grain_override(Some(1));
        let label = match pin_choice {
            Some(_) => "scalar",
            None => "dispatched",
        };
        for (si, &(n, p)) in SHAPES.iter().enumerate() {
            let (x, r, beta) = dense_design(n, p, 1300 + si as u64);
            let what = format!("{label} {n}x{p}");

            // Column-chunked Xᵀr is exactly serial on every backend
            // (dot4 lanes are bitwise single dots).
            let mut serial = vec![0.0; p];
            x.t_matvec_into(&r, &mut serial);
            let mut par = vec![0.0; p];
            x.t_matvec_par_into(&r, 4, &mut par);
            assert_bits_eq(&par, &serial, &format!("{what} t_matvec par"));

            // Row-chunked Xβ: bitwise on scalar (chunk-invariant axpy
            // loops), tolerance on SIMD (lane alignment shifts at chunk
            // boundaries).
            let mut serial_xb = vec![0.0; n];
            x.matvec_into(&beta, &mut serial_xb);
            let mut par_xb = vec![0.0; n];
            x.matvec_par_into(&beta, 4, &mut par_xb);
            match pin_choice {
                Some(_) => assert_bits_eq(&par_xb, &serial_xb, &format!("{what} matvec par")),
                None => assert_close(&par_xb, &serial_xb, &format!("{what} matvec par")),
            }

            // Carried residual sum: dense ignores it, sparse skips its
            // O(n) pass — both must equal the plain block kernel bitwise.
            let sr: f64 = r.iter().sum();
            let cols = 0..p;
            let mut plain = vec![0.0; p];
            x.block_t_matvec_into(cols.clone(), &r, &mut plain);
            let mut carried = vec![0.0; p];
            x.block_t_matvec_with_rsum_into(cols.clone(), &r, 123.456, &mut carried);
            assert_bits_eq(&carried, &plain, &format!("{what} dense rsum ignored"));

            let xs = sparse_design(n, p, 1700 + si as u64);
            let mut s_plain = vec![0.0; p];
            xs.block_t_matvec_into(cols.clone(), &r, &mut s_plain);
            let mut s_carried = vec![0.0; p];
            xs.block_t_matvec_with_rsum_into(cols.clone(), &r, sr, &mut s_carried);
            assert_bits_eq(&s_carried, &s_plain, &format!("{what} sparse rsum"));

            // Sparse parallel forms are bitwise serial at any chunking:
            // row-disjoint X̃β partitions, column-chunked X̃ᵀr.
            let mut s_serial = vec![0.0; n];
            xs.matvec_into(&beta, &mut s_serial);
            let mut s_par = vec![0.0; n];
            xs.matvec_par_into(&beta, 4, &mut s_par);
            assert_bits_eq(&s_par, &s_serial, &format!("{what} sparse matvec par"));

            let mut s_g = vec![0.0; p];
            xs.t_matvec_into(&r, &mut s_g);
            let mut s_g_par = vec![0.0; p];
            xs.t_matvec_par_into(&r, 4, &mut s_g_par);
            assert_bits_eq(&s_g_par, &s_g, &format!("{what} sparse t_matvec par"));
        }
        dfr::parallel::set_par_grain_override(None);
    }
}
