//! End-to-end equivalence gates for the out-of-core streaming solve
//! path: a `.dfrpack` design solved through the [`dfr::linalg::OocDesign`]
//! kernels must match the in-memory dense standardized solve to
//! ℓ₂ ≤ 1e-10 — for every screening rule and both response families —
//! while the peak-residency witness proves the design never occupied more
//! than two streaming blocks of RAM (plus the gathered reduced problem).
//!
//! Tests that pin the streaming block width or read the global residency
//! counters serialize on one mutex: the block override and the witness
//! watermark are process-wide.

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard, OnceLock};

use dfr::data::{Dataset, Response};
use dfr::linalg::{
    dense_materializations, ooc_peak_resident_bytes, ooc_reset_peak, set_ooc_block_override,
    DesignOps, Matrix, OocDesign,
};
use dfr::model_api::{Design, SglModel, SparseMode};
use dfr::path::{PathConfig, PathRunner};
use dfr::prelude::Groups;
use dfr::rng::Rng;
use dfr::screen::RuleKind;
use dfr::solver::SolverConfig;

/// One process-wide lock: `set_ooc_block_override` and the residency
/// watermark are global, so these tests must not interleave.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Unique scratch path for one test's pack file.
fn pack_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dfr-ooc-test-{}-{tag}.dfrpack", std::process::id()))
}

/// Raw (unstandardized) Gaussian design with per-column offsets and
/// scales, so pack-time standardization stats are nontrivial.
fn raw_design(seed: u64, n: usize, p: usize) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, p, |_, j| 2.0 * rng.gauss() + (j % 5) as f64 - 1.0)
}

/// Response from a sparse causal signal on the raw design.
fn response(raw: &Matrix, seed: u64, kind: Response) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x00C);
    let p = raw.ncols();
    let beta_true: Vec<f64> =
        (0..p).map(|j| if j % 7 == 0 { rng.normal(0.0, 1.5) } else { 0.0 }).collect();
    let xb = raw.matvec(&beta_true);
    match kind {
        Response::Linear => xb.iter().map(|v| v + rng.normal(0.0, 0.3)).collect(),
        Response::Logistic => {
            let mean = xb.iter().sum::<f64>() / xb.len() as f64;
            xb.iter()
                .map(|v| if v - mean + rng.normal(0.0, 0.3) > 0.0 { 1.0 } else { 0.0 })
                .collect()
        }
    }
}

/// The same problem as two [`Dataset`]s: one on the in-memory dense
/// standardized matrix, one streaming from a freshly packed `.dfrpack`.
/// p = 40 in groups of 5 with a 7-column streaming block, so every block
/// boundary except the last straddles a group.
fn paired_datasets(seed: u64, kind: Response, tag: &str) -> (Dataset, Dataset, PathBuf) {
    let (n, p, gsize) = (60usize, 40usize, 5usize);
    let raw = raw_design(seed, n, p);
    let mut y = response(&raw, seed, kind);
    if kind == Response::Linear {
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        y.iter_mut().for_each(|v| *v -= mean);
    }
    let group_sizes = vec![gsize; p / gsize];
    let groups = Groups::from_sizes(&group_sizes);
    let mut dense_std = raw.clone();
    dense_std.standardize_l2();
    let path = pack_path(tag);
    let ooc = dfr::linalg::ooc::pack_matrix(&raw, &path).unwrap();
    let dense_ds = Dataset {
        x: dense_std.into(),
        y: y.clone(),
        groups: groups.clone(),
        response: kind,
        name: "ooc-dense".into(),
    };
    let ooc_ds = Dataset {
        x: DesignOps::Ooc(ooc),
        y,
        groups,
        response: kind,
        name: "ooc-stream".into(),
    };
    (dense_ds, ooc_ds, path)
}

/// Solver settings tight enough that the comparison measures the
/// streaming kernels' floating-point perturbation, not optimizer slack.
fn cfg() -> PathConfig {
    PathConfig {
        path_len: 8,
        solver: SolverConfig { tol: 1e-12, max_iters: 200_000, ..Default::default() },
        ..PathConfig::default()
    }
}

const RULES: [RuleKind; 5] = [
    RuleKind::DfrSgl,
    RuleKind::Sparsegl,
    RuleKind::GapSafeSeq,
    RuleKind::GapSafeDyn,
    RuleKind::Tlfre,
];

#[test]
fn pathwise_ooc_matches_dense_linear_all_rules() {
    let _g = serial();
    set_ooc_block_override(Some(7));
    let (dense_ds, ooc_ds, path) = paired_datasets(3, Response::Linear, "linear");
    for rule in RULES {
        let dense_fit = PathRunner::new(&dense_ds, cfg()).rule(rule).run().unwrap();
        let ooc_fit = PathRunner::new(&ooc_ds, cfg())
            .rule(rule)
            .fixed_path(dense_fit.lambdas.clone())
            .run()
            .unwrap();
        let d = ooc_fit.l2_distance_to(&dense_fit);
        assert!(d <= 1e-10, "{}: ooc vs dense drift ℓ₂ = {d}", rule.name());
    }
    set_ooc_block_override(None);
    let _ = std::fs::remove_file(path);
}

#[test]
fn pathwise_ooc_matches_dense_logistic_all_rules() {
    let _g = serial();
    set_ooc_block_override(Some(7));
    let (dense_ds, ooc_ds, path) = paired_datasets(4, Response::Logistic, "logistic");
    for rule in RULES {
        let dense_fit = PathRunner::new(&dense_ds, cfg()).rule(rule).run().unwrap();
        let ooc_fit = PathRunner::new(&ooc_ds, cfg())
            .rule(rule)
            .fixed_path(dense_fit.lambdas.clone())
            .run()
            .unwrap();
        let d = ooc_fit.l2_distance_to(&dense_fit);
        assert!(d <= 1e-10, "{} logistic: drift ℓ₂ = {d}", rule.name());
    }
    set_ooc_block_override(None);
    let _ = std::fs::remove_file(path);
}

#[test]
fn asgl_ooc_matches_dense() {
    // Adaptive weights flow through the streaming col_means / PCA leg.
    let _g = serial();
    set_ooc_block_override(Some(7));
    let (dense_ds, ooc_ds, path) = paired_datasets(5, Response::Linear, "asgl");
    let c = PathConfig { adaptive: Some((0.1, 0.1)), ..cfg() };
    let dense_fit = PathRunner::new(&dense_ds, c.clone()).rule(RuleKind::DfrAsgl).run().unwrap();
    let ooc_fit = PathRunner::new(&ooc_ds, c)
        .rule(RuleKind::DfrAsgl)
        .fixed_path(dense_fit.lambdas.clone())
        .run()
        .unwrap();
    let d = ooc_fit.l2_distance_to(&dense_fit);
    assert!(d <= 1e-10, "aSGL ooc vs dense drift ℓ₂ = {d}");
    set_ooc_block_override(None);
    let _ = std::fs::remove_file(path);
}

/// The acceptance witness: a full pathwise fit on an [`OocDesign`] keeps
/// peak streaming-buffer residency at ≤ 2 blocks — strictly smaller than
/// the n×p design it replaces — and never densifies through the sparse
/// materialization counter either. Serial kernels are guaranteed here:
/// n·p = 2400 is far below the parallel grain, so no per-worker buffers
/// inflate the bound.
#[test]
fn ooc_fit_streams_within_two_blocks() {
    let _g = serial();
    set_ooc_block_override(Some(7));
    let (_, ooc_ds, path) = paired_datasets(6, Response::Linear, "witness");
    let (n, p) = (ooc_ds.n(), ooc_ds.p());
    let block_bytes = match &ooc_ds.x {
        DesignOps::Ooc(o) => {
            assert_eq!(o.block_cols(), 7, "override must pin the block width");
            o.block_bytes()
        }
        _ => unreachable!("fixture builds an ooc dataset"),
    };
    assert!(
        2 * block_bytes < n * p * 8,
        "witness is vacuous: two blocks ({}) do not undercut the dense design ({})",
        2 * block_bytes,
        n * p * 8,
    );
    let dense_before = dense_materializations();
    ooc_reset_peak();
    let fit = PathRunner::new(&ooc_ds, cfg()).rule(RuleKind::DfrSgl).run().unwrap();
    let peak = ooc_peak_resident_bytes();
    assert!(peak > 0, "fit never streamed a block — witness not exercised");
    assert!(
        peak <= 2 * block_bytes,
        "peak design residency {peak} exceeds two streaming blocks ({})",
        2 * block_bytes,
    );
    assert_eq!(
        dense_materializations(),
        dense_before,
        "ooc solve path materialized a dense design"
    );
    assert!(fit.active_vars_last() > 0, "fixture fit selected nothing");
    set_ooc_block_override(None);
    let _ = std::fs::remove_file(path);
}

/// `dfr pack` CSV ingest and in-memory packing agree bit for bit: same
/// header hash, same stats, same streamed standardized columns.
#[test]
fn pack_csv_roundtrip_matches_pack_matrix() {
    let _g = serial();
    let (n, p) = (23usize, 9usize);
    let raw = raw_design(11, n, p);
    let csv_path = pack_path("csv-src").with_extension("csv");
    let mut csv = String::from("h0,h1,h2,h3,h4,h5,h6,h7,h8\n");
    for i in 0..n {
        let row: Vec<String> = (0..p).map(|j| format!("{:.17e}", raw.col(j)[i])).collect();
        csv.push_str(&row.join(","));
        csv.push('\n');
    }
    std::fs::write(&csv_path, csv).unwrap();
    let a_path = pack_path("via-csv");
    let b_path = pack_path("via-matrix");
    let a = dfr::linalg::ooc::pack_csv(&csv_path, &a_path).unwrap();
    let b = dfr::linalg::ooc::pack_matrix(&raw, &b_path).unwrap();
    assert_eq!(a.nrows(), n);
    assert_eq!(a.ncols(), p);
    assert_eq!(a.content_hash(), b.content_hash(), "csv and matrix packs hash differently");
    assert_eq!(a.offsets(), b.offsets());
    assert_eq!(a.scales(), b.scales());
    let (mut ca, mut cb) = (vec![0.0; n], vec![0.0; n]);
    for j in 0..p {
        a.read_standardized_col_into(j, &mut ca);
        b.read_standardized_col_into(j, &mut cb);
        assert_eq!(ca, cb, "standardized column {j} differs between pack routes");
    }
    // Reopening sees the identical design.
    let reopened = OocDesign::open(&a_path).unwrap();
    assert_eq!(reopened.content_hash(), a.content_hash());
    for f in [csv_path, a_path, b_path] {
        let _ = std::fs::remove_file(f);
    }
}

/// Fitter-level contract: an `--ooc` design reports the streaming kernel,
/// predicts through the raw streaming matvec, and refuses CV with an
/// actionable error instead of panicking inside a fold gather.
#[test]
fn fitter_reports_ooc_kernel_and_rejects_cv() {
    let _g = serial();
    set_ooc_block_override(Some(7));
    let (n, p, gsize) = (60usize, 40usize, 5usize);
    let raw = raw_design(13, n, p);
    let mut y = response(&raw, 13, Response::Linear);
    let mean = y.iter().sum::<f64>() / y.len() as f64;
    y.iter_mut().for_each(|v| *v -= mean);
    let sizes = vec![gsize; p / gsize];
    let path = pack_path("fitter");
    let ooc = dfr::linalg::ooc::pack_matrix(&raw, &path).unwrap();
    let model = SglModel { path: cfg(), ..SglModel::default() };
    assert_eq!(Design::Ooc(&ooc).resolved_kernel(SparseMode::Auto), "ooc-stream");

    let mut fitter = model.clone().fitter();
    let fit = fitter.fit_at(&Design::Ooc(&ooc), &y, &sizes, Response::Linear, 7).unwrap();
    assert_eq!(fitter.kernel_variant(), Some("ooc-stream"));

    // Raw-scale batch prediction: the ooc streaming matvec must agree
    // with per-row dot products over the same raw design.
    let mut preds = vec![0.0; n];
    fit.decision_function_into(&Design::Ooc(&ooc), &mut preds);
    let mut expect = vec![0.0; n];
    fit.decision_function_into(&Design::Matrix(&raw), &mut expect);
    for (i, (a, b)) in preds.iter().zip(&expect).enumerate() {
        assert!((a - b).abs() <= 1e-10, "row {i}: ooc prediction {a} vs dense {b}");
    }

    // Same fixed λ through the dense route lands on the same raw-scale
    // coefficients.
    let mut dense_fitter = model.clone().fitter();
    let dense_fit =
        dense_fitter.fit_at(&Design::Matrix(&raw), &y, &sizes, Response::Linear, 7).unwrap();
    let d = dfr::linalg::l2_distance(&fit.coefficients, &dense_fit.coefficients);
    assert!(d <= 1e-8, "raw-scale coefficient drift ℓ₂ = {d}");

    // CV must bail with the documented message, not panic in gather_rows.
    let err = model
        .clone()
        .fitter()
        .fit_cv(&Design::Ooc(&ooc), &y, &sizes, Response::Linear)
        .unwrap_err();
    assert!(
        err.to_string().contains("cross-validation is not supported for out-of-core"),
        "unexpected CV error: {err}"
    );
    set_ooc_block_override(None);
    let _ = std::fs::remove_file(path);
}
