//! Solver-subsystem equivalence gates: the three inner solvers behind the
//! [`dfr::solver::Solver`] trait — FISTA, ATOS, and the group-major
//! block-coordinate solver (BCD) — must reach the same solutions to
//! ℓ₂ ≤ 1e-8 across every screening rule (DFR, sparsegl, GAP-safe
//! seq/dyn, DFR-aSGL), both loss families, dense and centered-implicit
//! sparse kernels, pathwise and at a single λ. The sparse BCD runs must
//! never materialize an n×p dense design (the thread-local witness
//! counter), and the default [`SolverKind`] stays FISTA so existing
//! results are bit-stable.

use dfr::data::{Dataset, Response};
use dfr::linalg::{dense_materializations, CenteredSparse, CscMatrix, DesignOps};
use dfr::loss::{Loss, LossKind};
use dfr::path::{PathConfig, PathFit, PathRunner};
use dfr::penalty::Penalty;
use dfr::prelude::Groups;
use dfr::rng::Rng;
use dfr::screen::RuleKind;
use dfr::solver::{solve, SolverConfig, SolverKind};

/// Genotype-like CSC design (mostly implicit zeros); `n > p` keeps the
/// squared loss strictly convex so all solvers share a unique optimum.
fn genotype(seed: u64, n: usize, p: usize) -> CscMatrix {
    let mut rng = Rng::new(seed);
    let mut col_ptr = vec![0usize];
    let mut row_idx = Vec::new();
    let mut values = Vec::new();
    for _ in 0..p {
        let maf = 0.05 + 0.10 * rng.uniform();
        for i in 0..n {
            let dosage = (rng.bernoulli(maf) as u8 + rng.bernoulli(maf) as u8) as f64;
            if dosage > 0.0 {
                row_idx.push(i);
                values.push(dosage);
            }
        }
        col_ptr.push(row_idx.len());
    }
    CscMatrix::new(n, p, col_ptr, row_idx, values)
}

fn response(geno: &CscMatrix, seed: u64, kind: Response) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0xB0CD);
    let p = geno.ncols();
    let beta_true: Vec<f64> =
        (0..p).map(|j| if j % 7 == 0 { rng.normal(0.0, 1.5) } else { 0.0 }).collect();
    let xb = geno.matvec(&beta_true);
    match kind {
        Response::Linear => xb.iter().map(|v| v + rng.normal(0.0, 0.3)).collect(),
        Response::Logistic => {
            let mean = xb.iter().sum::<f64>() / xb.len() as f64;
            xb.iter()
                .map(|v| if v - mean + rng.normal(0.0, 0.3) > 0.0 { 1.0 } else { 0.0 })
                .collect()
        }
    }
}

/// The same problem as a dense-kernel and a sparse-kernel [`Dataset`].
fn paired_datasets(seed: u64, kind: Response) -> (Dataset, Dataset) {
    let (n, p, gsize) = (60usize, 40usize, 5usize);
    let geno = genotype(seed, n, p);
    let mut y = response(&geno, seed, kind);
    if kind == Response::Linear {
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        y.iter_mut().for_each(|v| *v -= mean);
    }
    let groups = Groups::from_sizes(&vec![gsize; p / gsize]);
    let (dense_std, _) = geno.to_standardized_dense();
    let sparse = CenteredSparse::from_csc(&geno);
    let dense_ds = Dataset {
        x: dense_std.into(),
        y: y.clone(),
        groups: groups.clone(),
        response: kind,
        name: "solver-eq-dense".into(),
    };
    let sparse_ds = Dataset {
        x: DesignOps::Sparse(sparse),
        y,
        groups,
        response: kind,
        name: "solver-eq-sparse".into(),
    };
    (dense_ds, sparse_ds)
}

/// Solver settings tight enough that cross-algorithm distance measures
/// the shared optimum, not stopping-rule slack.
fn cfg(kind: SolverKind) -> PathConfig {
    PathConfig {
        path_len: 8,
        solver: SolverConfig { kind, tol: 1e-12, max_iters: 200_000, ..Default::default() },
        ..PathConfig::default()
    }
}

const SOLVERS: [SolverKind; 3] = [SolverKind::Fista, SolverKind::Atos, SolverKind::Bcd];

const RULES: [RuleKind; 5] = [
    RuleKind::DfrSgl,
    RuleKind::Sparsegl,
    RuleKind::GapSafeSeq,
    RuleKind::GapSafeDyn,
    RuleKind::Tlfre,
];

/// Pathwise fits of `ds` with each solver on one shared λ grid (derived by
/// the first solver), asserting pairwise agreement against the first.
fn assert_pathwise_agreement(ds: &Dataset, rule: RuleKind, adaptive: Option<(f64, f64)>) {
    let mut reference: Option<PathFit> = None;
    for kind in SOLVERS {
        let mut c = cfg(kind);
        c.adaptive = adaptive;
        let mut runner = PathRunner::new(ds, c).rule(rule);
        if let Some(r) = &reference {
            runner = runner.fixed_path(r.lambdas.clone());
        }
        let fit = runner.run().unwrap();
        if let Some(r) = &reference {
            let d = fit.l2_distance_to(r);
            assert!(
                d <= 1e-8,
                "{} vs fista on {} ({:?}): ℓ₂ = {d}",
                kind.name(),
                rule.name(),
                ds.response
            );
        } else {
            reference = Some(fit);
        }
    }
}

#[test]
fn pathwise_dense_linear_all_rules() {
    let (dense_ds, _) = paired_datasets(1, Response::Linear);
    for rule in RULES {
        assert_pathwise_agreement(&dense_ds, rule, None);
    }
}

#[test]
fn pathwise_dense_logistic_all_rules() {
    let (dense_ds, _) = paired_datasets(2, Response::Logistic);
    for rule in RULES {
        assert_pathwise_agreement(&dense_ds, rule, None);
    }
}

#[test]
fn pathwise_dense_asgl_both_losses() {
    for (seed, kind) in [(3, Response::Linear), (4, Response::Logistic)] {
        let (dense_ds, _) = paired_datasets(seed, kind);
        assert_pathwise_agreement(&dense_ds, RuleKind::DfrAsgl, Some((0.1, 0.1)));
    }
}

/// Sparse-kernel pathwise runs agree across solvers AND never densify —
/// BCD's block kernels run centered-implicit end to end.
#[test]
fn pathwise_sparse_agrees_and_never_materializes() {
    for (seed, kind) in [(5, Response::Linear), (6, Response::Logistic)] {
        let (_, sparse_ds) = paired_datasets(seed, kind);
        for rule in RULES {
            let before = dense_materializations();
            assert_pathwise_agreement(&sparse_ds, rule, None);
            assert_eq!(
                dense_materializations(),
                before,
                "{} {kind:?}: sparse solver run materialized a dense design",
                rule.name()
            );
        }
    }
}

/// Sparse BCD matches the *dense* FISTA solution — cross-kernel AND
/// cross-solver at once.
#[test]
fn sparse_bcd_matches_dense_fista() {
    for (seed, kind) in [(7, Response::Linear), (8, Response::Logistic)] {
        let (dense_ds, sparse_ds) = paired_datasets(seed, kind);
        let fista = PathRunner::new(&dense_ds, cfg(SolverKind::Fista))
            .rule(RuleKind::DfrSgl)
            .run()
            .unwrap();
        let bcd = PathRunner::new(&sparse_ds, cfg(SolverKind::Bcd))
            .rule(RuleKind::DfrSgl)
            .fixed_path(fista.lambdas.clone())
            .run()
            .unwrap();
        let d = bcd.l2_distance_to(&fista);
        assert!(d <= 1e-8, "{kind:?}: sparse BCD vs dense FISTA ℓ₂ = {d}");
    }
}

/// Single-λ equivalence on the raw solver entry points, both losses,
/// dense and sparse kernels (sparse with the densification witness).
#[test]
fn single_lambda_all_solvers_both_losses_both_kernels() {
    for (seed, resp, lk) in [
        (9, Response::Linear, LossKind::Squared),
        (10, Response::Logistic, LossKind::Logistic),
    ] {
        let (dense_ds, sparse_ds) = paired_datasets(seed, resp);
        let p = dense_ds.p();
        let pen = Penalty::sgl(dense_ds.groups.clone(), 0.95);
        let tight = |kind| SolverConfig {
            kind,
            tol: 1e-12,
            max_iters: 200_000,
            ..Default::default()
        };

        let dense_loss = Loss::new(lk, dense_ds.x.view(), &dense_ds.y);
        let lam_max = crate_lambda_max(&pen, &dense_loss, p);
        let lam = 0.3 * lam_max;
        let fista = solve(&dense_loss, &pen, lam, &vec![0.0; p], &tight(SolverKind::Fista));
        for kind in [SolverKind::Atos, SolverKind::Bcd] {
            let r = solve(&dense_loss, &pen, lam, &vec![0.0; p], &tight(kind));
            let d = dfr::linalg::l2_distance(&r.beta, &fista.beta);
            assert!(d <= 1e-8, "{} dense {resp:?}: ℓ₂ = {d}", kind.name());
        }

        let sparse_loss = Loss::new(lk, sparse_ds.x.view(), &sparse_ds.y);
        let before = dense_materializations();
        for kind in SOLVERS {
            let r = solve(&sparse_loss, &pen, lam, &vec![0.0; p], &tight(kind));
            let d = dfr::linalg::l2_distance(&r.beta, &fista.beta);
            assert!(d <= 1e-8, "{} sparse {resp:?}: ℓ₂ = {d}", kind.name());
        }
        assert_eq!(
            dense_materializations(),
            before,
            "single-λ sparse solves materialized a dense design"
        );
    }
}

fn crate_lambda_max(pen: &Penalty, loss: &Loss, p: usize) -> f64 {
    dfr::path::lambda_max(pen, &loss.gradient(&vec![0.0; p]))
}

/// Bit-stability guard: the default solver stays FISTA everywhere a
/// default config is built.
#[test]
fn default_solver_kind_is_fista() {
    assert_eq!(SolverConfig::default().kind, SolverKind::Fista);
    assert_eq!(PathConfig::default().solver.kind, SolverKind::Fista);
    assert_eq!(
        dfr::model_api::SglModel::default().path.solver.kind,
        SolverKind::Fista
    );
    assert_eq!(
        dfr::model_api::SglModel::default().with_solver(SolverKind::Bcd).path.solver.kind,
        SolverKind::Bcd
    );
    assert_eq!(SolverKind::parse("bcd").unwrap(), SolverKind::Bcd);
    assert!(SolverKind::parse("newton").is_err());
}
