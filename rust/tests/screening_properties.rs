//! Property tests for the screening theory (Propositions 2.1–2.4 / B.1–B.4
//! of the paper) over randomized problems, via the in-crate testkit.
//!
//! * theoretical rules recover the exact support (Props 2.1 / 2.3),
//! * DFR + KKT loop preserves pathwise solutions (the working guarantee of
//!   Props 2.2 / 2.4),
//! * GAP safe never discards an active variable (exactness),
//! * α ∈ {0, 1} reductions (Appendix A.4),
//! * λ₁ is the exact entry point of the first predictor (Appendix A.3).

use dfr::linalg::{CenteredSparse, CscMatrix, Matrix, ReducedDesign};
use dfr::loss::{Loss, LossKind};
use dfr::norms::{dual_sgl_norm, eps_g, epsilon_norm, tau_g};
use dfr::path::lambda_max;
use dfr::penalty::Penalty;
use dfr::prelude::Groups;
use dfr::rng::Rng;
use dfr::screen::dfr::screen_theoretical;
use dfr::screen::tlfre;
use dfr::solver::{solve, SolverConfig};
use dfr::testkit::{check, random_problem};

fn tight() -> SolverConfig {
    SolverConfig { tol: 1e-11, max_iters: 200_000, ..Default::default() }
}

/// Props 2.1 / 2.3: with the gradient at λ_{k+1} itself, the theoretical
/// candidate sets contain exactly the active support (up to solver noise).
#[test]
fn theoretical_rules_recover_exact_support() {
    check("theoretical-support", 12, random_problem, |rp| {
        let ds = &rp.data.dataset;
        if rp.alpha == 0.0 {
            return Ok(()); // variable layer degenerate at group-lasso limit
        }
        let pen = Penalty::sgl(ds.groups.clone(), rp.alpha);
        let loss = Loss::new(LossKind::Squared, &ds.x, &ds.y);
        let p = ds.p();
        let lam1 = lambda_max(&pen, &loss.gradient(&vec![0.0; p]));
        let lam = 0.5 * lam1;
        let sol = solve(&loss, &pen, lam, &vec![0.0; p], &tight());
        let grad = loss.gradient(&sol.beta);
        let cands = screen_theoretical(&pen, &grad, &sol.beta, lam);
        // Every active variable must be in the theoretical candidate set...
        for (i, &b) in sol.beta.iter().enumerate() {
            if b.abs() > 1e-7 && !cands.vars.contains(&i) {
                return Err(format!("active var {i} (β={b}) missing from theoretical set"));
            }
        }
        // ...and flagged-but-zero variables must sit at the KKT boundary
        // (margin within tolerance), not deep inside the active region.
        for &i in &cands.vars {
            if sol.beta[i] == 0.0 {
                let margin = grad[i].abs() - lam * rp.alpha;
                if margin > 1e-4 * lam {
                    return Err(format!(
                        "var {i} flagged with margin {margin:.3e} but solver kept it 0"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Working guarantee of Props 2.2 / 2.4 + KKT loop: full pathwise DFR
/// reaches the same solutions as no screening, across random α.
#[test]
fn dfr_path_preserves_solutions_randomized() {
    check("dfr-preserves-solutions", 8, random_problem, |rp| {
        let ds = &rp.data.dataset;
        let cfg = dfr::path::PathConfig {
            alpha: rp.alpha,
            path_len: 8,
            solver: SolverConfig { tol: 1e-9, max_iters: 100_000, ..Default::default() },
            ..Default::default()
        };
        let cmp = dfr::path::compare_with_no_screen(ds, &cfg, dfr::screen::RuleKind::DfrSgl)
            .map_err(|e| e.to_string())?;
        if cmp.l2_distance > 5e-4 {
            return Err(format!("ℓ₂ drift {} at α={}", cmp.l2_distance, rp.alpha));
        }
        Ok(())
    });
}

/// Same, adaptive variant (Props B.2 / B.4).
#[test]
fn dfr_asgl_path_preserves_solutions_randomized() {
    check("dfr-asgl-preserves-solutions", 5, random_problem, |rp| {
        let ds = &rp.data.dataset;
        let cfg = dfr::path::PathConfig {
            alpha: rp.alpha.clamp(0.3, 0.97),
            path_len: 6,
            adaptive: Some((0.1, 0.1)),
            solver: SolverConfig { tol: 1e-9, max_iters: 100_000, ..Default::default() },
            ..Default::default()
        };
        let cmp = dfr::path::compare_with_no_screen(ds, &cfg, dfr::screen::RuleKind::DfrAsgl)
            .map_err(|e| e.to_string())?;
        if cmp.l2_distance > 5e-4 {
            return Err(format!("aSGL ℓ₂ drift {}", cmp.l2_distance));
        }
        Ok(())
    });
}

/// GAP safe exactness: screening from ANY primal point never discards a
/// variable active at the screened λ.
#[test]
fn gap_safe_is_safe_randomized() {
    check("gap-safe-safety", 10, random_problem, |rp| {
        let ds = &rp.data.dataset;
        if ds.response != dfr::data::Response::Linear {
            return Ok(());
        }
        let alpha = rp.alpha.clamp(0.05, 0.95);
        let pen = Penalty::sgl(ds.groups.clone(), alpha);
        let loss = Loss::new(LossKind::Squared, &ds.x, &ds.y);
        let p = ds.p();
        let lam1 = lambda_max(&pen, &loss.gradient(&vec![0.0; p]));
        let lam = 0.45 * lam1;
        let sol = solve(&loss, &pen, lam, &vec![0.0; p], &tight());
        // Screen from a deliberately bad primal point (the null vector).
        let cands = dfr::screen::gap_safe::screen_at(&pen, &ds.x, &ds.y, &vec![0.0; p], lam);
        for (i, &b) in sol.beta.iter().enumerate() {
            if b.abs() > 1e-7 && !cands.vars.contains(&i) {
                return Err(format!("GAP safe unsafely discarded active var {i} (β={b})"));
            }
        }
        Ok(())
    });
}

/// Appendix A.4 limit identities for the ε-norm / τ_g machinery.
#[test]
fn epsilon_norm_alpha_limits() {
    check(
        "epsilon-limits",
        40,
        |rng| {
            let p_g = 1 + rng.below(12);
            (rng.gauss_vec(p_g), p_g)
        },
        |(xs, p_g)| {
            // α = 1: τ_g = 1, ε_g = 0 → ε-norm = ℓ∞.
            let e1 = epsilon_norm(xs, eps_g(1.0, *p_g));
            let linf = xs.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            if (e1 - linf).abs() > 1e-9 * (1.0 + linf) {
                return Err(format!("α=1 limit broken: {e1} vs ℓ∞ {linf}"));
            }
            // α = 0: τ_g = √p_g, ε_g = 1 → ε-norm = ℓ₂.
            let e0 = epsilon_norm(xs, eps_g(0.0, *p_g));
            let l2 = xs.iter().map(|v| v * v).sum::<f64>().sqrt();
            if (e0 - l2).abs() > 1e-9 * (1.0 + l2) {
                return Err(format!("α=0 limit broken: {e0} vs ℓ₂ {l2}"));
            }
            if tau_g(0.5, *p_g) <= 0.0 {
                return Err("τ_g must be positive".into());
            }
            Ok(())
        },
    );
}

/// The ε-norm is nondecreasing in ε, pinned between its α-limit endpoints
/// ℓ∞ (ε = 0) and ℓ₂ (ε = 1) — the interpolation the two-layer dual-ball
/// decomposition rides on.
#[test]
fn epsilon_norm_monotone_in_eps() {
    check(
        "epsilon-monotone",
        40,
        |rng| rng.gauss_vec(1 + rng.below(12)),
        |xs| {
            let linf = xs.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            let l2 = xs.iter().map(|v| v * v).sum::<f64>().sqrt();
            let mut prev = f64::NEG_INFINITY;
            for k in 0..=10 {
                let eps = k as f64 / 10.0;
                let e = epsilon_norm(xs, eps);
                if e < prev - 1e-12 * (1.0 + prev.abs()) {
                    return Err(format!("ε-norm decreased at ε={eps}: {e} < {prev}"));
                }
                if e < linf - 1e-9 * (1.0 + linf) || e > l2 + 1e-9 * (1.0 + l2) {
                    return Err(format!(
                        "ε-norm {e} outside [ℓ∞, ℓ₂] = [{linf}, {l2}] at ε={eps}"
                    ));
                }
                prev = e;
            }
            Ok(())
        },
    );
}

/// A random ξ vector with a random group layout, α, and strictly positive
/// adaptive weights — the input shape of the TLFre gauge machinery.
fn random_dual_point(rng: &mut Rng) -> (Vec<f64>, Groups, f64, Vec<f64>, Vec<f64>) {
    let sizes = Groups::random_sizes(6 + rng.below(20), 1, 6, rng);
    let groups = Groups::from_sizes(&sizes);
    let p = groups.p();
    let m = groups.m();
    let xi: Vec<f64> = rng.gauss_vec(p).iter().map(|v| 3.0 * v).collect();
    let alpha = 0.05 + 0.9 * rng.uniform();
    let v: Vec<f64> = (0..p).map(|_| 0.2 + 2.0 * rng.uniform()).collect();
    let w: Vec<f64> = (0..m).map(|_| 0.2 + 2.0 * rng.uniform()).collect();
    (xi, groups, alpha, v, w)
}

/// On unit weights the per-group bisection gauge must agree with the
/// independent ε-norm implementation of the SGL dual norm (clamped at the
/// feasibility threshold 1): two derivations, one number.
#[test]
fn tlfre_gauge_matches_dual_norm_on_unit_weights() {
    check("tlfre-gauge-vs-dual", 25, random_dual_point, |(xi, groups, alpha, _, _)| {
        let pen = Penalty::sgl(groups.clone(), *alpha);
        let gauge = tlfre::feasibility_gauge(xi, &pen)
            .ok_or("gauge undefined on a finite penalty")?;
        let dual = dual_sgl_norm(xi, groups, *alpha);
        let expect = dual.max(1.0);
        if (gauge - expect).abs() > 1e-7 * (1.0 + expect) {
            return Err(format!("gauge {gauge} vs ε-norm dual {expect} (α={alpha})"));
        }
        Ok(())
    });
}

/// Hölder certificate on arbitrary positive adaptive weights: scaling ξ by
/// its gauge lands inside the dual ball, so `⟨ξ/gauge, β⟩ ≤ Ω(β)` for
/// every β — the exact feasibility property TLFre's safety rests on.
#[test]
fn tlfre_gauge_certifies_dual_feasibility() {
    check("tlfre-gauge-feasible", 25, random_dual_point, |(xi, groups, alpha, v, w)| {
        let pen = Penalty::asgl(groups.clone(), *alpha, v.clone(), w.clone());
        let gauge = tlfre::feasibility_gauge(xi, &pen)
            .ok_or("gauge undefined on a finite penalty")?;
        if gauge < 1.0 {
            return Err(format!("gauge {gauge} below the feasibility clamp"));
        }
        let mut rng = Rng::new(xi.len() as u64 ^ 0xD0A1);
        for _ in 0..20 {
            let beta = rng.gauss_vec(xi.len());
            let inner: f64 =
                xi.iter().zip(&beta).map(|(a, b)| a * b).sum::<f64>() / gauge;
            let omega = pen.value(&beta);
            if inner > omega * (1.0 + 1e-9) + 1e-12 {
                return Err(format!("Hölder violated: ⟨ξ/gauge, β⟩ = {inner} > Ω = {omega}"));
            }
        }
        Ok(())
    });
}

/// Adaptive-weight scaling identity: scaling both weight families by c
/// equals scaling the point by 1/c — `gauge_{cv,cw}(ξ) = gauge_{v,w}(ξ/c)`
/// exactly (clamps included). With c = 2 the division is float-exact, so
/// the two bisections walk identical brackets.
#[test]
fn tlfre_gauge_weight_scaling() {
    check("tlfre-gauge-scaling", 25, random_dual_point, |(xi, groups, alpha, v, w)| {
        let c = 2.0;
        let scaled_pen = Penalty::asgl(
            groups.clone(),
            *alpha,
            v.iter().map(|x| c * x).collect(),
            w.iter().map(|x| c * x).collect(),
        );
        let pen = Penalty::asgl(groups.clone(), *alpha, v.clone(), w.clone());
        let xi_over_c: Vec<f64> = xi.iter().map(|x| x / c).collect();
        let a = tlfre::feasibility_gauge(xi, &scaled_pen).ok_or("gauge undefined")?;
        let b = tlfre::feasibility_gauge(&xi_over_c, &pen).ok_or("gauge undefined")?;
        if (a - b).abs() > 1e-12 * (1.0 + b) {
            return Err(format!("scaling identity broken: {a} vs {b}"));
        }
        Ok(())
    });
}

/// TLFre exactness on random problems: screening between two λ values
/// from the previous solution — tight or deliberately sloppy (the δ-
/// inflation must absorb the inexactness) — never discards a variable
/// active at the screened λ.
#[test]
fn tlfre_is_safe_randomized() {
    check("tlfre-safety", 10, random_problem, |rp| {
        let ds = &rp.data.dataset;
        if ds.response != dfr::data::Response::Linear {
            return Ok(());
        }
        let alpha = rp.alpha.clamp(0.05, 0.95);
        let pen = Penalty::sgl(ds.groups.clone(), alpha);
        let loss = Loss::new(LossKind::Squared, &ds.x, &ds.y);
        let p = ds.p();
        let lam1 = lambda_max(&pen, &loss.gradient(&vec![0.0; p]));
        let (lam_prev, lam_next) = (0.6 * lam1, 0.45 * lam1);
        let truth = solve(&loss, &pen, lam_next, &vec![0.0; p], &tight());
        let sloppy = SolverConfig { tol: 1e-3, max_iters: 50, ..Default::default() };
        for cfg in [tight(), sloppy] {
            let prev = solve(&loss, &pen, lam_prev, &vec![0.0; p], &cfg);
            let cands =
                tlfre::screen_between(&pen, &ds.x, &ds.y, &prev.beta, lam_prev, lam_next);
            for (i, &b) in truth.beta.iter().enumerate() {
                if b.abs() > 1e-7 && cands.vars.binary_search(&i).is_err() {
                    return Err(format!(
                        "TLFre (prev tol {:.0e}) unsafely discarded active var {i} (β={b})",
                        cfg.tol
                    ));
                }
            }
        }
        Ok(())
    });
}

/// A random grouped design plus a random sorted non-empty variable
/// subset — the input shape of every screening-reduced gather.
fn random_reduction(rng: &mut Rng) -> (Matrix, Groups, Vec<usize>) {
    let sizes = Groups::random_sizes(20 + rng.below(60), 2, 9, rng);
    let groups = Groups::from_sizes(&sizes);
    let p = groups.p();
    let n = 10 + rng.below(20);
    let x = Matrix::from_fn(n, p, |_, _| rng.gauss());
    let mut idx: Vec<usize> = (0..p).filter(|_| rng.bernoulli(0.4)).collect();
    if idx.is_empty() {
        idx.push(rng.below(p));
    }
    (x, groups, idx)
}

/// Validate one recorded offset list against the subset it was built for:
/// the blocks must tile `[0, idx.len())` exactly (start 0, sentinel at the
/// end, no empty blocks), each block must draw from a single original
/// group, consecutive blocks from different ones — and the whole list must
/// equal the restricted penalty's group offsets.
fn offsets_tile_exactly(
    offsets: &[usize],
    idx: &[usize],
    groups: &Groups,
) -> Result<(), String> {
    if offsets.first() != Some(&0) || offsets.last() != Some(&idx.len()) {
        return Err(format!("offsets {offsets:?} do not span [0, {}]", idx.len()));
    }
    if offsets.windows(2).any(|w| w[0] >= w[1]) {
        return Err(format!("offsets {offsets:?} contain an empty or inverted block"));
    }
    for w in offsets.windows(2) {
        let block = &idx[w[0]..w[1]];
        let g0 = groups.group_of(block[0]);
        if block.iter().any(|&j| groups.group_of(j) != g0) {
            return Err(format!("block {block:?} mixes original groups"));
        }
    }
    for w in offsets.windows(3) {
        if groups.group_of(idx[w[0]]) == groups.group_of(idx[w[1]]) {
            return Err("consecutive blocks share an original group".into());
        }
    }
    let (restricted, _) = groups.restrict(idx);
    if restricted.offsets() != offsets {
        return Err(format!(
            "offsets {offsets:?} disagree with Groups::restrict {:?}",
            restricted.offsets()
        ));
    }
    Ok(())
}

/// Reduced group-block offsets always tile the reduced design exactly —
/// dense sources, including across incremental (prefix-reusing) updates.
#[test]
fn reduced_group_offsets_tile_dense() {
    check("reduced-offsets-dense", 25, random_reduction, |(x, groups, idx)| {
        let mut red = ReducedDesign::new();
        let ncols = red.update_grouped(x, idx, groups).ncols();
        if ncols != idx.len() {
            return Err(format!("gathered {ncols} columns for {} indices", idx.len()));
        }
        offsets_tile_exactly(red.group_offsets(), idx, groups)?;
        // Incremental update: grow the subset (shared sorted prefix keeps
        // columns in place) and the offsets must still tile exactly.
        let mut grown = idx.clone();
        for j in 0..groups.p() {
            if !grown.contains(&j) && j % 3 == 0 {
                grown.push(j);
            }
        }
        grown.sort_unstable();
        red.update_grouped(x, &grown, groups);
        offsets_tile_exactly(red.group_offsets(), &grown, groups)
    });
}

/// The same tiling property through the centered-implicit sparse gather.
#[test]
fn reduced_group_offsets_tile_sparse() {
    check("reduced-offsets-sparse", 15, random_reduction, |(x, groups, idx)| {
        let sparse = CenteredSparse::from_csc(&CscMatrix::from_dense(x, 0.5));
        let mut red = ReducedDesign::new();
        let ncols = red.update_grouped(&sparse, idx, groups).ncols();
        if ncols != idx.len() {
            return Err(format!("gathered {ncols} sparse columns for {}", idx.len()));
        }
        offsets_tile_exactly(red.group_offsets(), idx, groups)
    });
}

/// λ₁ = ‖∇f(0)‖*_sgl is exactly the entry point of the first predictor.
#[test]
fn lambda_max_is_exact_entry_point() {
    check("lambda-max-entry", 6, random_problem, |rp| {
        let ds = &rp.data.dataset;
        if ds.response != dfr::data::Response::Linear {
            return Ok(());
        }
        let alpha = rp.alpha.clamp(0.1, 1.0);
        let pen = Penalty::sgl(ds.groups.clone(), alpha);
        let loss = Loss::new(LossKind::Squared, &ds.x, &ds.y);
        let p = ds.p();
        let lam1 = lambda_max(&pen, &loss.gradient(&vec![0.0; p]));
        let above = solve(&loss, &pen, lam1 * 1.001, &vec![0.0; p], &tight());
        if above.beta.iter().any(|&b| b != 0.0) {
            return Err("non-null model above λ₁".into());
        }
        let below = solve(&loss, &pen, lam1 * 0.97, &vec![0.0; p], &tight());
        if below.beta.iter().all(|&b| b == 0.0) {
            return Err("null model well below λ₁".into());
        }
        Ok(())
    });
}
