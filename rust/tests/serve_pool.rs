//! Integration tests for the multi-tenant serving subsystem
//! ([`dfr::serve`]): pool-vs-dedicated-fitter equivalence, LRU bounds,
//! predict coalescing, counter reconciliation, eviction, and the full
//! NDJSON serve loop driven by an in-memory script.

use dfr::prelude::*;
use dfr::report::Json;
use dfr::serve::{
    serve, CvRequest, FitRequest, FitterPool, PoolConfig, PredictRequest, Request, ServeOptions,
};
use std::io::Cursor;

/// Deterministic toy regression problem (xorshift rows, linear signal).
fn toy_problem(seed: u64, n: usize, p: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1;
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        (state >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
    };
    let x: Vec<Vec<f64>> = (0..n).map(|_| (0..p).map(|_| next()).collect()).collect();
    let y: Vec<f64> = x
        .iter()
        .map(|row| 1.5 * row[0] - 2.0 * row[1] + 0.5 * row[p - 1] + 0.05 * next())
        .collect();
    (x, y)
}

/// Short path so every test fit stays cheap.
fn test_model() -> SglModel {
    SglModel { path: PathConfig { path_len: 8, ..PathConfig::default() }, ..SglModel::default() }
}

fn pool_with(max_entries: usize, max_bytes: usize) -> FitterPool {
    FitterPool::new(PoolConfig { model: test_model(), threads: 2, max_entries, max_bytes })
}

fn fit_request(tenant: &str, x: &[Vec<f64>], y: &[f64], groups: &[usize], idx: usize) -> FitRequest {
    FitRequest {
        id: None,
        tenant: tenant.to_string(),
        x: x.to_vec(),
        y: y.to_vec(),
        groups: groups.to_vec(),
        response: Response::Linear,
        rule: None,
        alpha: None,
        path_len: None,
        lambda_idx: Some(idx),
    }
}

fn json_rows(x: &[Vec<f64>]) -> Json {
    Json::Arr(x.iter().map(|r| Json::Arr(r.iter().map(|&v| Json::Num(v)).collect())).collect())
}

#[test]
fn interleaved_tenants_match_dedicated_fitters() {
    let pool = pool_with(8, usize::MAX);
    let groups = [3, 3, 4];
    let tenants = ["alice", "bob", "carol"];
    let problems: Vec<_> = (0..3).map(|i| toy_problem(40 + i as u64, 30, 10)).collect();
    let idx = 5;

    // One batch interleaving all three tenants' fits with predicts
    // against the very models those fits produce (heavy lane runs
    // before the predict lane, so this is legal in a single batch).
    let mut batch = Vec::new();
    for (t, (x, y)) in tenants.iter().zip(&problems) {
        batch.push(Request::Fit(fit_request(t, x, y, &groups, idx)));
    }
    for (t, (x, _)) in tenants.iter().zip(&problems) {
        batch.push(Request::Predict(PredictRequest {
            id: None,
            tenant: (*t).to_string(),
            x: x[..4].to_vec(),
        }));
    }
    let replies = pool.submit_batch(batch);
    for r in &replies {
        assert!(r.is_ok(), "batch reply failed: {}", r.render());
    }

    // The pool result must be l2-identical to a dedicated per-tenant
    // fitter (same pipeline pieces ⇒ expect bitwise equality).
    for (t, (x, y)) in tenants.iter().zip(&problems) {
        let served = pool.model_of(t).expect("model stored after fit");
        let mut dedicated = test_model().fitter();
        let reference =
            dedicated.fit_at(&Design::rows(x), y, &groups, Response::Linear, idx).unwrap();
        let l2: f64 = served
            .coefficients
            .iter()
            .zip(&reference.coefficients)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(l2 <= 1e-10, "tenant {t}: pool vs dedicated l2 = {l2:e}");
        assert_eq!(served.intercept, reference.intercept, "tenant {t}: intercept");
        assert_eq!(served.lambda, reference.lambda, "tenant {t}: lambda");
    }
}

#[test]
fn repeat_fit_hits_prepared_and_path_caches() {
    let pool = pool_with(8, usize::MAX);
    let (x, y) = toy_problem(7, 24, 9);
    let req = fit_request("t", &x, &y, &[3, 3, 3], 3);

    let cold = pool.fit(&req).unwrap();
    assert!(!cold.prepared_cached && !cold.path_cached, "first fit must miss");
    let warm = pool.fit(&req).unwrap();
    assert!(warm.prepared_cached && warm.path_cached, "second fit must hit");
    assert_eq!(cold.lambda, warm.lambda);
    assert_eq!(cold.active, warm.active);

    // Re-selection at another λ index also rides the cached path.
    let resel = pool.fit(&fit_request("t", &x, &y, &[3, 3, 3], 6)).unwrap();
    assert!(resel.prepared_cached && resel.path_cached);
    assert_eq!(resel.lambda_idx, 6);

    let ts = pool.tenant_stats("t");
    assert_eq!(ts.fits(), 3);
    assert_eq!(ts.prepared_misses(), 1);
    assert_eq!(ts.prepared_hits(), 2);
    assert_eq!(ts.path_hits(), 2);
}

#[test]
fn lru_eviction_honors_entry_bound() {
    let pool = pool_with(2, usize::MAX);
    for seed in 0..4 {
        let (x, y) = toy_problem(100 + seed, 20, 6);
        pool.fit(&fit_request("hoarder", &x, &y, &[3, 3], 2)).unwrap();
    }
    let (len, _, evictions) = pool.prepared_cache_stats();
    assert!(len <= 2, "prepared cache over entry bound: {len}");
    assert_eq!(evictions, 2);
    let (plen, _, pev) = pool.path_cache_stats();
    assert!(plen <= 2, "path cache over entry bound: {plen}");
    assert_eq!(pev, 2);
    // 2 prepared + 2 path evictions, all attributed to their inserter.
    assert_eq!(pool.tenant_stats("hoarder").evictions(), 4);
}

#[test]
fn lru_eviction_honors_byte_bound() {
    // A 1-byte budget forces every insert to evict everything else —
    // but never the entry just inserted, so the cache stays usable.
    let pool = pool_with(64, 1);
    for seed in 0..3 {
        let (x, y) = toy_problem(200 + seed, 20, 6);
        let out = pool.fit(&fit_request("b", &x, &y, &[3, 3], 2)).unwrap();
        assert!(!out.prepared_cached && !out.path_cached);
    }
    let (len, _, evictions) = pool.prepared_cache_stats();
    assert_eq!(len, 1, "byte bound must keep exactly the newest entry");
    assert_eq!(evictions, 2);
}

#[test]
fn coalesced_batch_predict_matches_sequential() {
    let pool = pool_with(8, usize::MAX);
    let (x, y) = toy_problem(11, 30, 10);
    pool.fit(&fit_request("t", &x, &y, &[5, 5], 4)).unwrap();

    let chunks: Vec<Vec<Vec<f64>>> = vec![x[0..3].to_vec(), x[3..10].to_vec(), x[10..11].to_vec()];
    let sequential: Vec<Vec<f64>> =
        chunks.iter().map(|c| pool.predict("t", c).unwrap()).collect();

    let batch: Vec<Request> = chunks
        .iter()
        .enumerate()
        .map(|(k, c)| {
            Request::Predict(PredictRequest {
                id: Some(k as f64),
                tenant: "t".to_string(),
                x: c.clone(),
            })
        })
        .collect();
    let replies = pool.submit_batch(batch);
    assert_eq!(replies.len(), 3);
    for (k, (reply, expect)) in replies.iter().zip(&sequential).enumerate() {
        assert!(reply.is_ok(), "predict reply failed: {}", reply.render());
        // Round-trip through the wire form: render → parse.
        let j = Json::parse(&reply.render()).unwrap();
        assert_eq!(j.get("id").and_then(Json::as_f64), Some(k as f64));
        assert_eq!(j.get("coalesced").and_then(Json::as_f64), Some(3.0));
        let preds: Vec<f64> = j
            .get("predictions")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(&preds, expect, "request {k}: coalesced != sequential");
    }

    let stats = pool.stats_json();
    let coal = stats.get("coalescing").unwrap();
    assert_eq!(coal.get("batches").and_then(Json::as_f64), Some(1.0));
    assert_eq!(coal.get("predicts").and_then(Json::as_f64), Some(3.0));
}

#[test]
fn cv_caches_cell_and_respects_one_se() {
    let pool = pool_with(8, usize::MAX);
    let (x, y) = toy_problem(21, 36, 8);
    let req = CvRequest {
        id: None,
        tenant: "cvr".to_string(),
        x,
        y,
        groups: vec![4, 4],
        response: Response::Linear,
        rule: None,
        alpha: None,
        folds: Some(3),
        one_se: false,
    };
    let cold = pool.cv(&req).unwrap();
    assert!(!cold.cv_cached && !cold.prepared_cached);
    assert_eq!(cold.chosen_idx, cold.best_idx);

    let warm = pool.cv(&CvRequest { one_se: true, ..req }).unwrap();
    assert!(warm.cv_cached && warm.prepared_cached, "second cv must hit the cell cache");
    assert_eq!(warm.chosen_idx, warm.best_1se_idx);
    assert_eq!(warm.best_idx, cold.best_idx);

    let ts = pool.tenant_stats("cvr");
    assert_eq!(ts.cvs(), 2);
    assert_eq!(ts.cv_hits(), 1);
}

#[test]
fn stats_counters_reconcile() {
    let pool = pool_with(8, usize::MAX);
    let (xa, ya) = toy_problem(61, 24, 6);
    let (xb, yb) = toy_problem(62, 24, 6);
    pool.fit(&fit_request("a", &xa, &ya, &[3, 3], 2)).unwrap();
    pool.fit(&fit_request("a", &xa, &ya, &[3, 3], 2)).unwrap();
    pool.fit(&fit_request("b", &xb, &yb, &[3, 3], 2)).unwrap();
    pool.predict("a", &xa[..2]).unwrap();

    // Every fit/cv probes the prepared cache exactly once.
    for name in ["a", "b"] {
        let ts = pool.tenant_stats(name);
        assert_eq!(
            ts.prepared_hits() + ts.prepared_misses(),
            ts.fits() + ts.cvs(),
            "tenant {name}: prepared probes must reconcile with fits+cvs"
        );
    }
    assert_eq!(pool.tenant_stats("a").predicts(), 1);

    // The stats verb reply is valid JSON and mirrors the pool state.
    let replies = pool.submit_batch(vec![Request::Stats { id: Some(9.0) }]);
    let j = Json::parse(&replies[0].render()).unwrap();
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
    let stats = j.get("stats").unwrap();
    assert_eq!(stats.get("models").and_then(Json::as_usize), Some(2));
    let verbs = stats.get("verbs").unwrap();
    // Direct pool calls bypass the histograms; the counters still cover
    // everything routed through submit_batch (none here).
    assert!(verbs.get("fit").unwrap().get("count").and_then(Json::as_f64).is_some());
    let prepared = stats.get("caches").unwrap().get("prepared").unwrap();
    let (len, bytes, _) = pool.prepared_cache_stats();
    assert_eq!(prepared.get("entries").and_then(Json::as_usize), Some(len));
    assert_eq!(prepared.get("bytes").and_then(Json::as_usize), Some(bytes));
    let ta = stats.get("tenants").unwrap().get("a").unwrap();
    assert_eq!(ta.get("fits").and_then(Json::as_f64), Some(2.0));
    assert_eq!(ta.get("prepared_hits").and_then(Json::as_f64), Some(1.0));
}

#[test]
fn evict_drops_model_and_owned_entries() {
    let pool = pool_with(8, usize::MAX);
    let (xg, yg) = toy_problem(71, 20, 6);
    let (xs, ys) = toy_problem(72, 20, 6);
    pool.fit(&fit_request("gone", &xg, &yg, &[3, 3], 2)).unwrap();
    pool.fit(&fit_request("stays", &xs, &ys, &[3, 3], 2)).unwrap();

    let (had, dropped) = pool.evict("gone");
    assert!(had);
    assert_eq!(dropped, 2, "one prepared + one path entry");
    assert!(pool.model_of("gone").is_none());
    assert!(pool.model_of("stays").is_some());
    let (len, _, evictions) = pool.prepared_cache_stats();
    assert_eq!(len, 1);
    assert_eq!(evictions, 0, "explicit drops are not LRU evictions");
    assert_eq!(pool.evict("gone"), (false, 0), "second evict is a no-op");
}

#[test]
fn serve_loop_runs_scripted_session() {
    let pool = pool_with(8, usize::MAX);
    let (x, y) = toy_problem(33, 24, 6);
    let fit_line = Json::obj(vec![
        ("verb", Json::Str("fit".into())),
        ("id", Json::Num(1.0)),
        ("tenant", Json::Str("cli".into())),
        ("x", json_rows(&x)),
        ("y", Json::Arr(y.iter().map(|&v| Json::Num(v)).collect())),
        ("groups", Json::Arr(vec![Json::Num(3.0), Json::Num(3.0)])),
        ("lambda_idx", Json::Num(4.0)),
    ])
    .render();
    let predict_line = Json::obj(vec![
        ("verb", Json::Str("predict".into())),
        ("id", Json::Num(2.0)),
        ("tenant", Json::Str("cli".into())),
        ("x", json_rows(&x[..5])),
    ])
    .render();
    let script = format!(
        "{fit_line}\n{predict_line}\nnot json\n\n{{\"verb\":\"stats\",\"id\":3}}\n\
         {{\"verb\":\"evict\",\"tenant\":\"cli\",\"id\":4}}\n{{\"verb\":\"shutdown\",\"id\":5}}\n"
    );

    let mut out = Vec::new();
    let summary =
        serve(&pool, Cursor::new(script), &mut out, &ServeOptions { batch_max: 4 }).unwrap();
    assert!(summary.shutdown, "shutdown verb must end the loop");
    assert_eq!(summary.requests, 6, "blank line skipped, bad line counted");
    assert!(summary.batches >= 1);

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(lines.len(), 6, "one reply per non-blank request line");

    let expect = [
        ("fit", Some(1.0), true),
        ("predict", Some(2.0), true),
        ("parse", None, false),
        ("stats", Some(3.0), true),
        ("evict", Some(4.0), true),
        ("shutdown", Some(5.0), true),
    ];
    for (j, (verb, id, ok)) in lines.iter().zip(expect) {
        assert_eq!(j.get("verb").and_then(Json::as_str), Some(verb), "line {}", j.render());
        assert_eq!(j.get("id").and_then(Json::as_f64), id);
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(ok));
    }
    assert!(lines[2].get("error").and_then(Json::as_str).is_some());
    assert_eq!(
        lines[1].get("predictions").and_then(Json::as_arr).map(Vec::len),
        Some(5),
        "predict echoes one prediction per row"
    );
    assert_eq!(lines[4].get("had_model").and_then(Json::as_bool), Some(true));
    assert!(pool.model_of("cli").is_none(), "evict removed the model");
}
