//! Serving-API equivalence properties: the persistent `SglFitter` must be
//! a pure performance layer — identical results (ℓ₂ ≤ 1e-10) to the
//! deprecated one-shot `SglModel::fit_*` shims across response families
//! and input layouts (including sparse CSC), with zero new workspace
//! allocations once warm.
#![allow(deprecated)] // the shims are the parity baseline under test

use dfr::data::Response;
use dfr::linalg::{l2_distance, CscMatrix, Matrix};
use dfr::model_api::{Design, SglModel};
use dfr::path::PathConfig;
use dfr::rng::Rng;
use dfr::solver::SolverConfig;

/// Unstandardized raw regression rows (offset + per-column scale) with a
/// sparse-group signal.
fn raw_problem(seed: u64, n: usize, p: usize, logistic: bool) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let beta: Vec<f64> =
        (0..p).map(|j| if j % 5 == 0 { rng.normal(0.0, 1.5) } else { 0.0 }).collect();
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..p).map(|j| 2.0 + (1.0 + j as f64 / 4.0) * rng.gauss()).collect())
        .collect();
    let y: Vec<f64> = rows
        .iter()
        .map(|r| {
            let eta: f64 =
                r.iter().zip(&beta).map(|(x, b)| x * b).sum::<f64>() + rng.normal(0.0, 0.5);
            if logistic {
                if eta > 2.0 * rng.gauss() { 1.0 } else { 0.0 }
            } else {
                eta
            }
        })
        .collect();
    (rows, y)
}

fn model(path_len: usize) -> SglModel {
    SglModel {
        path: PathConfig {
            path_len,
            solver: SolverConfig { tol: 1e-8, max_iters: 20_000, ..Default::default() },
            ..PathConfig::default()
        },
        cv_folds: 3,
        ..SglModel::default()
    }
}

/// The deprecated shims and the fitter agree exactly for fit_at, both
/// response families.
#[test]
fn fitter_matches_shim_fit_at_linear_and_logistic() {
    for (seed, resp) in [(31u64, Response::Linear), (32, Response::Logistic)] {
        let (rows, y) = raw_problem(seed, 70, 12, resp == Response::Logistic);
        let m = model(10);
        let shim = m.fit_at(&rows, &y, &[4, 4, 4], resp, 9).unwrap();
        let mut fitter = m.fitter();
        let served = fitter.fit_at(&Design::rows(&rows), &y, &[4, 4, 4], resp, 9).unwrap();
        let d = l2_distance(&shim.coefficients, &served.coefficients);
        assert!(d <= 1e-10, "{resp:?}: shim vs fitter drift ℓ₂ = {d}");
        assert!((shim.intercept - served.intercept).abs() <= 1e-10);
        assert_eq!(shim.lambda_idx, served.lambda_idx);
    }
}

/// Parity holds for CV selection too (same folds, same λ grid, same
/// selected index, same raw-scale coefficients).
#[test]
fn fitter_matches_shim_fit_cv() {
    let (rows, y) = raw_problem(33, 90, 12, false);
    let m = model(8);
    let shim = m.fit_cv(&rows, &y, &[4, 4, 4], Response::Linear).unwrap();
    let mut fitter = m.fitter();
    let served = fitter.fit_cv(&Design::rows(&rows), &y, &[4, 4, 4], Response::Linear).unwrap();
    assert_eq!(shim.lambda_idx, served.lambda_idx, "CV picked a different λ");
    let d = l2_distance(&shim.coefficients, &served.coefficients);
    assert!(d <= 1e-10, "CV coefficients drift ℓ₂ = {d}");
    // A repeated fit_cv on unchanged data is served from the CV-cell
    // cache: no fold fits, no path solve, identical answer.
    let solves_before = fitter.pool_checkouts();
    let cv_fits_before = fitter.cv_engine().pool_checkouts();
    let again = fitter.fit_cv(&Design::rows(&rows), &y, &[4, 4, 4], Response::Linear).unwrap();
    assert_eq!(fitter.cv_hits(), 1, "CV cell was recomputed");
    assert_eq!(fitter.pool_checkouts(), solves_before, "warm fit_cv re-solved the path");
    assert_eq!(
        fitter.cv_engine().pool_checkouts(),
        cv_fits_before,
        "warm fit_cv re-ran fold fits"
    );
    assert_eq!(again.lambda_idx, served.lambda_idx);
    assert!(l2_distance(&again.coefficients, &served.coefficients) <= 1e-12);
}

/// A CSC design must produce the same fit as the identical dense design.
#[test]
fn sparse_csc_fit_matches_dense() {
    // Sparse-ish raw design: dosage-style entries, ~75% exact zeros.
    let (n, p) = (80usize, 24usize);
    let mut rng = Rng::new(34);
    let dense = Matrix::from_fn(n, p, |_, _| {
        if rng.bernoulli(0.25) { 1.0 + rng.uniform() } else { 0.0 }
    });
    let y: Vec<f64> = (0..n)
        .map(|i| 1.5 * dense.get(i, 0) - 2.0 * dense.get(i, 5) + 0.3 * rng.gauss())
        .collect();
    let csc = CscMatrix::from_dense(&dense, 0.0);
    assert!(csc.density() < 0.5, "fixture is not sparse enough to be meaningful");
    // Tight solver tolerance: the CSC and dense standardizations differ in
    // the last float bits (different summation orders), so this comparison
    // must measure that perturbation, not optimizer slack. Kernel choice
    // is pinned to dense so this test covers the CSC *ingest* path
    // regardless of the fixture's sampled density; the centered-implicit
    // sparse kernels have their own gate (rust/tests/sparse_equivalence.rs).
    let mut m = model(10);
    m.path.solver.tol = 1e-10;
    m.path.solver.max_iters = 100_000;
    m.sparse = dfr::model_api::SparseMode::Off;
    let mut dense_fitter = m.fitter();
    let from_dense = dense_fitter
        .fit_at(&Design::Matrix(&dense), &y, &[6, 6, 6, 6], Response::Linear, 9)
        .unwrap();
    let mut sparse_fitter = m.fitter();
    let from_csc = sparse_fitter
        .fit_at(&Design::Csc(&csc), &y, &[6, 6, 6, 6], Response::Linear, 9)
        .unwrap();
    let d = l2_distance(&from_dense.coefficients, &from_csc.coefficients);
    assert!(d <= 1e-10, "CSC vs dense drift ℓ₂ = {d}");
    assert!((from_dense.intercept - from_csc.intercept).abs() <= 1e-10);
    // And all borrowed layouts agree with the rows layout.
    let rows: Vec<Vec<f64>> =
        (0..n).map(|i| (0..p).map(|j| dense.get(i, j)).collect()).collect();
    let cm: Vec<f64> = dense.as_slice().to_vec();
    let rm: Vec<f64> = rows.iter().flatten().copied().collect();
    for design in [
        Design::rows(&rows),
        Design::col_major(n, p, &cm),
        Design::row_major(n, p, &rm),
    ] {
        let mut fitter = m.fitter();
        let fit = fitter.fit_at(&design, &y, &[6, 6, 6, 6], Response::Linear, 9).unwrap();
        let d = l2_distance(&from_dense.coefficients, &fit.coefficients);
        assert!(d <= 1e-10, "{} vs dense drift ℓ₂ = {d}", design.layout_name());
    }
}

/// Repeated fits on a warm fitter allocate no new workspaces: the path
/// pool stays at one slot, the CV pool at `threads` slots, and requests
/// that change nothing are served from the caches without a solve.
#[test]
fn repeated_fits_allocate_no_new_workspaces() {
    let (rows, y) = raw_problem(35, 60, 12, false);
    let m = model(8);
    let mut fitter = m.fitter();
    let design = Design::rows(&rows);
    let first = fitter.fit_at(&design, &y, &[4, 4, 4], Response::Linear, 7).unwrap();
    let (slots, checkouts) = (fitter.pool_slots(), fitter.pool_checkouts());
    assert_eq!(slots, 1);
    assert_eq!(checkouts, 1);
    // 20 more requests: λ re-selections are cache hits; forced re-solves
    // (clear_path_cache) reuse the one pooled workspace.
    for req in 0..20 {
        if req % 4 == 3 {
            fitter.clear_path_cache();
        }
        let idx = 2 + (req % 6);
        let fit = fitter.fit_at(&design, &y, &[4, 4, 4], Response::Linear, idx).unwrap();
        assert_eq!(fit.lambda, first.path_fit.lambdas[idx], "λ grid drifted");
    }
    assert_eq!(fitter.pool_slots(), 1, "workspace pool grew under repeated fits");
    assert_eq!(fitter.prepared_misses(), 1, "prepared dataset was rebuilt");
    assert_eq!(fitter.prepared_hits(), 20);
    // Exactly the forced re-solves hit the pool; everything else was cached.
    assert_eq!(fitter.pool_checkouts(), 1 + 5, "unexpected solve count");
    // The warm fitter still reproduces the first answer exactly.
    let again = fitter.fit_at(&design, &y, &[4, 4, 4], Response::Linear, 7).unwrap();
    let d = l2_distance(&again.coefficients, &first.coefficients);
    assert!(d <= 1e-12, "warm fitter drifted: ℓ₂ = {d}");
}

/// Changing the data (new fingerprint) re-ingests; switching back to a
/// previously-seen design is a miss too (the cache holds one dataset),
/// but results stay exact.
#[test]
fn fitter_detects_design_changes() {
    let (rows_a, y_a) = raw_problem(36, 50, 8, false);
    let (rows_b, y_b) = raw_problem(37, 50, 8, false);
    let m = model(6);
    let mut fitter = m.fitter();
    let a1 = fitter.fit_at(&Design::rows(&rows_a), &y_a, &[4, 4], Response::Linear, 5).unwrap();
    let b = fitter.fit_at(&Design::rows(&rows_b), &y_b, &[4, 4], Response::Linear, 5).unwrap();
    assert_eq!(fitter.prepared_misses(), 2, "dataset swap went unnoticed");
    let mut cold = m.fitter();
    let b_cold =
        cold.fit_at(&Design::rows(&rows_b), &y_b, &[4, 4], Response::Linear, 5).unwrap();
    assert!(l2_distance(&b.coefficients, &b_cold.coefficients) <= 1e-12);
    let a2 = fitter.fit_at(&Design::rows(&rows_a), &y_a, &[4, 4], Response::Linear, 5).unwrap();
    assert!(l2_distance(&a1.coefficients, &a2.coefficients) <= 1e-12);
}
