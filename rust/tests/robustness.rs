//! Robustness suite: adversarial inputs and injected faults.
//!
//! Two halves:
//!
//! 1. **Adversarial inputs** — NaN/∞ design entries, degenerate responses,
//!    broken groupings, empty/single-row designs, dense and sparse — must
//!    come back as structured `DfrError`s (matched here by their Display
//!    text, since the vendored anyhow shim formats eagerly), never panics.
//! 2. **Injected faults** — via `dfr::faults::with_plan`: NaN gradients,
//!    forced backtracking failure, truncated iteration budgets, poisoned
//!    fitter caches. Every one must surface as an accurate `SolveStatus`
//!    (or a transparent recompute) with finite coefficients.
//!
//! Plus the KKT-cap escalation equivalence: with `max_kkt_rounds = 0`,
//! every violating path point escalates to a full no-screening solve, and
//! the resulting path must match a from-scratch no-screen fit within the
//! same ℓ₂ bound the screening-equivalence suite pins.

use dfr::data::{Response, SyntheticConfig};
use dfr::faults::{with_plan, FaultPlan};
use dfr::groups::Groups;
use dfr::linalg::{CscMatrix, Matrix};
use dfr::loss::{Loss, LossKind};
use dfr::model_api::{Design, SglModel, SparseMode};
use dfr::path::{PathConfig, PathRunner};
use dfr::penalty::Penalty;
use dfr::rng::Rng;
use dfr::screen::RuleKind;
use dfr::solver::{solve, SolveStatus, SolverConfig, SolverKind};

/// Well-conditioned raw rows with a sparse signal (the "good" baseline the
/// adversarial cases perturb).
fn good_problem(seed: u64, n: usize, p: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let beta: Vec<f64> =
        (0..p).map(|j| if j % 4 == 0 { rng.normal(0.0, 1.5) } else { 0.0 }).collect();
    let rows: Vec<Vec<f64>> =
        (0..n).map(|_| (0..p).map(|_| rng.gauss()).collect()).collect();
    let y: Vec<f64> = rows
        .iter()
        .map(|r| r.iter().zip(&beta).map(|(x, b)| x * b).sum::<f64>() + rng.normal(0.0, 0.3))
        .collect();
    (rows, y)
}

fn small_model() -> SglModel {
    SglModel {
        path: PathConfig { path_len: 6, ..PathConfig::default() },
        ..SglModel::default()
    }
}

/// Fit and return the error text (panics the test if the fit succeeded).
fn expect_fit_error(rows: &[Vec<f64>], y: &[f64], sizes: &[usize], resp: Response) -> String {
    let mut fitter = small_model().fitter();
    match fitter.fit_at(&Design::rows(rows), y, sizes, resp, 5) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("adversarial input was accepted"),
    }
}

// ---------------------------------------------------------------------------
// Adversarial inputs → structured errors, never panics
// ---------------------------------------------------------------------------

#[test]
fn nan_design_entry_is_rejected_with_coordinates() {
    let (mut rows, y) = good_problem(1, 30, 8);
    rows[7][3] = f64::NAN;
    let msg = expect_fit_error(&rows, &y, &[4, 4], Response::Linear);
    assert!(msg.contains("X[7, 3]") && msg.contains("not finite"), "got: {msg}");
}

#[test]
fn infinite_design_entry_is_rejected() {
    let (mut rows, y) = good_problem(2, 30, 8);
    rows[0][0] = f64::INFINITY;
    let msg = expect_fit_error(&rows, &y, &[4, 4], Response::Linear);
    assert!(msg.contains("not finite"), "got: {msg}");
}

#[test]
fn nan_response_entry_is_rejected() {
    let (rows, mut y) = good_problem(3, 30, 8);
    y[11] = f64::NAN;
    let msg = expect_fit_error(&rows, &y, &[4, 4], Response::Linear);
    assert!(msg.contains("y[11]") && msg.contains("not finite"), "got: {msg}");
}

#[test]
fn all_constant_design_is_rejected() {
    let rows: Vec<Vec<f64>> = (0..20).map(|_| vec![3.0, -1.0, 0.0, 7.5]).collect();
    let mut rng = Rng::new(4);
    let y: Vec<f64> = (0..20).map(|_| rng.gauss()).collect();
    let msg = expect_fit_error(&rows, &y, &[2, 2], Response::Linear);
    assert!(msg.contains("constant"), "got: {msg}");
}

#[test]
fn single_constant_column_is_benign() {
    let (mut rows, y) = good_problem(5, 40, 8);
    for r in &mut rows {
        r[2] = 1.0; // an intercept-like column among varying ones
    }
    let mut fitter = small_model().fitter();
    let fit = fitter.fit_at(&Design::rows(&rows), &y, &[4, 4], Response::Linear, 5).unwrap();
    assert_eq!(fit.coefficients[2], 0.0, "constant column must stay out of the model");
    assert!(fit.status().is_success());
}

#[test]
fn single_row_design_is_a_structured_error() {
    let rows = vec![vec![1.0, 2.0, 3.0, 4.0]];
    let y = vec![1.5];
    // With one observation every column is trivially constant: the design
    // carries no variation to fit — structured rejection, not a panic.
    let msg = expect_fit_error(&rows, &y, &[2, 2], Response::Linear);
    assert!(msg.contains("constant"), "got: {msg}");
}

#[test]
fn constant_response_is_rejected_as_degenerate() {
    let (rows, _) = good_problem(12, 30, 8);
    let y = vec![2.5; 30];
    let msg = expect_fit_error(&rows, &y, &[4, 4], Response::Linear);
    assert!(msg.contains("degenerate response") && msg.contains("zero variance"), "got: {msg}");
}

#[test]
fn empty_design_is_rejected() {
    let rows: Vec<Vec<f64>> = Vec::new();
    let msg = expect_fit_error(&rows, &[], &[], Response::Linear);
    assert!(msg.contains("empty design"), "got: {msg}");
}

#[test]
fn empty_group_is_rejected() {
    let (rows, y) = good_problem(6, 30, 8);
    let msg = expect_fit_error(&rows, &y, &[4, 0, 4], Response::Linear);
    assert!(msg.contains("group 1") && msg.contains("size 0"), "got: {msg}");
}

#[test]
fn group_size_mismatch_is_rejected() {
    let (rows, y) = good_problem(7, 30, 8);
    let msg = expect_fit_error(&rows, &y, &[4, 3], Response::Linear);
    assert!(msg.contains("sum to 7") && msg.contains("8 columns"), "got: {msg}");
}

#[test]
fn response_length_mismatch_is_rejected() {
    let (rows, y) = good_problem(8, 30, 8);
    let msg = expect_fit_error(&rows, &y[..29], &[4, 4], Response::Linear);
    assert!(msg.contains("dimension mismatch"), "got: {msg}");
}

#[test]
fn singleton_groups_fit_cleanly() {
    let (rows, y) = good_problem(9, 50, 8);
    let mut fitter = small_model().fitter();
    let fit = fitter.fit_at(&Design::rows(&rows), &y, &[1; 8], Response::Linear, 5).unwrap();
    assert!(fit.status().is_success());
    assert!(fit.coefficients.iter().all(|c| c.is_finite()));
}

#[test]
fn one_class_logistic_is_rejected() {
    let (rows, _) = good_problem(10, 40, 8);
    let y = vec![1.0; 40];
    let msg = expect_fit_error(&rows, &y, &[4, 4], Response::Logistic);
    assert!(msg.contains("single-class"), "got: {msg}");
}

#[test]
fn sparse_kernel_rejects_nan_and_all_zero_designs() {
    // NaN hidden in CSC nonzeros, routed through the sparse kernel.
    let csc = CscMatrix::new(4, 2, vec![0, 2, 4], vec![0, 2, 1, 3], vec![1.0, f64::NAN, 2.0, 1.0]);
    let y = vec![0.5, -0.5, 1.0, 0.0];
    let model = SglModel { sparse: SparseMode::On, ..small_model() };
    let mut fitter = model.fitter();
    let msg = match fitter.fit_at(&Design::Csc(&csc), &y, &[1, 1], Response::Linear, 5) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("NaN CSC entry was accepted"),
    };
    assert!(msg.contains("not finite"), "got: {msg}");

    // Every column implicit-zero: constant design.
    let zero = CscMatrix::new(4, 2, vec![0, 0, 0], vec![], vec![]);
    let msg = match fitter.fit_at(&Design::Csc(&zero), &y, &[1, 1], Response::Linear, 5) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("all-zero CSC design was accepted"),
    };
    assert!(msg.contains("constant"), "got: {msg}");
}

#[test]
fn invalid_hyperparameters_are_structured_errors() {
    let (rows, y) = good_problem(11, 30, 8);
    for (name, cfg) in [
        ("alpha", PathConfig { alpha: f64::NAN, ..PathConfig::default() }),
        ("alpha", PathConfig { alpha: 1.5, ..PathConfig::default() }),
        ("path_end_ratio", PathConfig { path_end_ratio: 0.0, ..PathConfig::default() }),
        (
            "tol",
            PathConfig {
                solver: SolverConfig { tol: -1.0, ..SolverConfig::default() },
                ..PathConfig::default()
            },
        ),
        (
            "max_seconds",
            PathConfig {
                solver: SolverConfig { max_seconds: f64::NAN, ..SolverConfig::default() },
                ..PathConfig::default()
            },
        ),
        ("gamma", PathConfig { adaptive: Some((-0.5, 0.1)), ..PathConfig::default() }),
    ] {
        let model = SglModel { path: cfg, ..SglModel::default() };
        let mut fitter = model.fitter();
        let err = fitter
            .fit_at(&Design::rows(&rows), &y, &[4, 4], Response::Linear, 0)
            .expect_err(&format!("invalid {name} was accepted"));
        assert!(err.to_string().contains("invalid parameter"), "{name}: {err}");
    }
}

// ---------------------------------------------------------------------------
// Fault injection → accurate statuses, finite iterates, no panics
// ---------------------------------------------------------------------------

/// Small standardized solver problem for direct `solve` calls.
fn solver_problem(seed: u64, n: usize, p: usize) -> (Matrix, Vec<f64>, Groups) {
    let mut rng = Rng::new(seed);
    let mut x = Matrix::from_fn(n, p, |_, _| rng.gauss());
    x.standardize_l2();
    let beta: Vec<f64> =
        (0..p).map(|j| if j % 3 == 0 { rng.normal(0.0, 2.0) } else { 0.0 }).collect();
    let mut y = x.matvec(&beta);
    y.iter_mut().for_each(|v| *v += rng.normal(0.0, 0.1));
    (x, y, Groups::even(p, 4))
}

fn lambda_for(loss: &Loss, groups: &Groups, alpha: f64, frac: f64, p: usize) -> f64 {
    frac * dfr::norms::dual_sgl_norm(&loss.gradient(&vec![0.0; p]), groups, alpha)
}

#[test]
fn nan_gradient_degrades_with_status_and_finite_beta() {
    let (x, y, g) = solver_problem(20, 50, 16);
    let loss = Loss::new(LossKind::Squared, &x, &y);
    let pen = Penalty::sgl(g.clone(), 0.95);
    let lam = lambda_for(&loss, &g, 0.95, 0.1, 16);
    let cfg = SolverConfig { tol: 1e-10, max_iters: 20_000, ..SolverConfig::default() };
    let res = with_plan(
        FaultPlan { nan_gradient_after: Some(2), ..FaultPlan::default() },
        || solve(&loss, &pen, lam, &vec![0.0; 16], &cfg),
    );
    assert!(res.beta.iter().all(|b| b.is_finite()), "NaN leaked into β");
    // The one-shot NaN either trips divergence detection (and the clean
    // FISTA restart finishes the job) or is classified as divergence.
    assert!(
        matches!(res.status, SolveStatus::FellBack { .. } | SolveStatus::Diverged),
        "status {:?}",
        res.status
    );
}

#[test]
fn forced_bcd_backtracking_failure_falls_back_to_fista() {
    let (x, y, g) = solver_problem(21, 50, 16);
    let loss = Loss::new(LossKind::Squared, &x, &y);
    let pen = Penalty::sgl(g.clone(), 0.95);
    let lam = lambda_for(&loss, &g, 0.95, 0.1, 16);
    let cfg = SolverConfig { kind: SolverKind::Bcd, tol: 1e-8, ..SolverConfig::default() };
    let res = with_plan(
        FaultPlan { fail_backtrack_for: Some(SolverKind::Bcd), ..FaultPlan::default() },
        || solve(&loss, &pen, lam, &vec![0.0; 16], &cfg),
    );
    assert_eq!(
        res.status,
        SolveStatus::FellBack { from: SolverKind::Bcd, to: SolverKind::Fista },
        "ladder must record the degraded route"
    );
    assert!(res.converged());
    // The fallback must land on the same solution a clean FISTA run finds.
    let clean = solve(
        &loss,
        &pen,
        lam,
        &vec![0.0; 16],
        &SolverConfig { tol: 1e-8, ..SolverConfig::default() },
    );
    let d = dfr::linalg::l2_distance(&res.beta, &clean.beta);
    assert!(d < 1e-4, "fallback drifted from clean solve: ℓ₂ = {d}");
}

#[test]
fn forced_fista_failure_without_escape_reports_failure() {
    let (x, y, g) = solver_problem(22, 40, 12);
    let loss = Loss::new(LossKind::Squared, &x, &y);
    let pen = Penalty::sgl(g.clone(), 0.95);
    let lam = lambda_for(&loss, &g, 0.95, 0.1, 12);
    let cfg = SolverConfig { tol: 1e-10, ..SolverConfig::default() };
    // FISTA forced to fail, and the ladder's fallback is also FISTA: no
    // escape route. The status must be a non-success, not a fake converge.
    let res = with_plan(
        FaultPlan { fail_backtrack_for: Some(SolverKind::Fista), ..FaultPlan::default() },
        || solve(&loss, &pen, lam, &vec![0.0; 12], &cfg),
    );
    assert!(!res.status.is_success(), "broken certificate reported as {:?}", res.status);
    assert!(res.beta.iter().all(|b| b.is_finite()));
}

#[test]
fn truncated_iteration_budget_reports_budget_exhausted() {
    let (x, y, g) = solver_problem(23, 50, 16);
    let loss = Loss::new(LossKind::Squared, &x, &y);
    let pen = Penalty::sgl(g.clone(), 0.95);
    let lam = lambda_for(&loss, &g, 0.95, 0.05, 16);
    let cfg = SolverConfig { tol: 1e-12, max_iters: 20_000, ..SolverConfig::default() };
    let res = with_plan(
        FaultPlan { truncate_iters: Some(3), ..FaultPlan::default() },
        || solve(&loss, &pen, lam, &vec![0.0; 16], &cfg),
    );
    assert_eq!(res.status, SolveStatus::BudgetExhausted);
    assert!(res.iterations <= 3 + 3, "budget ignored: {} iterations", res.iterations);
    assert!(res.beta.iter().all(|b| b.is_finite()));
}

#[test]
fn wall_clock_budget_reports_budget_exhausted() {
    let (x, y, g) = solver_problem(24, 80, 32);
    let loss = Loss::new(LossKind::Squared, &x, &y);
    let pen = Penalty::sgl(g.clone(), 0.95);
    let lam = lambda_for(&loss, &g, 0.95, 0.01, 32);
    // A tolerance no solver meets in 32 iterations plus a budget that has
    // already expired at the first clock check.
    let cfg = SolverConfig {
        tol: 1e-16,
        max_iters: 1_000_000,
        max_seconds: 1e-9,
        ..SolverConfig::default()
    };
    let res = solve(&loss, &pen, lam, &vec![0.0; 32], &cfg);
    assert_eq!(res.status, SolveStatus::BudgetExhausted);
    assert!(res.beta.iter().all(|b| b.is_finite()));
}

#[test]
fn stall_window_reports_stalled() {
    let (x, y, g) = solver_problem(25, 60, 24);
    let loss = Loss::new(LossKind::Squared, &x, &y);
    let pen = Penalty::sgl(g.clone(), 0.95);
    let lam = lambda_for(&loss, &g, 0.95, 0.05, 24);
    // An unreachable tolerance with a small stall window: once the
    // objective plateaus at machine precision, the stall guardrail (not
    // the iteration cap) must end the solve.
    let cfg = SolverConfig {
        tol: 1e-16,
        max_iters: 1_000_000,
        stall_window: 50,
        ..SolverConfig::default()
    };
    let res = solve(&loss, &pen, lam, &vec![0.0; 24], &cfg);
    assert_eq!(res.status, SolveStatus::Stalled);
    assert!(res.beta.iter().all(|b| b.is_finite()));
}

#[test]
fn poisoned_fitter_cache_recomputes_transparently() {
    let (rows, y) = good_problem(26, 50, 8);
    let mut fitter = small_model().fitter();
    let first = fitter.fit_at(&Design::rows(&rows), &y, &[4, 4], Response::Linear, 5).unwrap();
    assert_eq!(fitter.prepared_misses(), 1);
    fitter.testkit_poison_cache();
    // The integrity stamp no longer matches: the fitter must re-ingest
    // (a second miss) and produce bit-identical results — never serve the
    // poisoned entry, never panic.
    let second = fitter.fit_at(&Design::rows(&rows), &y, &[4, 4], Response::Linear, 5).unwrap();
    assert_eq!(fitter.prepared_misses(), 2, "poisoned entry was served");
    assert_eq!(first.coefficients, second.coefficients);
    assert_eq!(first.intercept, second.intercept);
}

#[test]
fn fault_plans_do_not_leak_across_solves() {
    let (x, y, g) = solver_problem(27, 40, 12);
    let loss = Loss::new(LossKind::Squared, &x, &y);
    let pen = Penalty::sgl(g.clone(), 0.95);
    let lam = lambda_for(&loss, &g, 0.95, 0.1, 12);
    let cfg = SolverConfig::default();
    let _ = with_plan(
        FaultPlan { truncate_iters: Some(2), ..FaultPlan::default() },
        || solve(&loss, &pen, lam, &vec![0.0; 12], &cfg),
    );
    // Outside the plan the same solve must be healthy again.
    let clean = solve(&loss, &pen, lam, &vec![0.0; 12], &cfg);
    assert_eq!(clean.status, SolveStatus::Converged);
}

// ---------------------------------------------------------------------------
// KKT-cap escalation: certified equivalence with a no-screen solve
// ---------------------------------------------------------------------------

#[test]
fn kkt_cap_escalation_matches_no_screen_path() {
    let gd = SyntheticConfig {
        n: 60,
        p: 90,
        rho: 0.3,
        ..SyntheticConfig::default()
    }
    .generate(31);
    let cfg = PathConfig {
        path_len: 10,
        // Every KKT violation immediately exhausts the cap, forcing the
        // escalation path at any violating λ.
        max_kkt_rounds: 0,
        solver: SolverConfig { tol: 1e-9, max_iters: 100_000, ..SolverConfig::default() },
        ..PathConfig::default()
    };
    let screened =
        PathRunner::new(&gd.dataset, cfg.clone()).rule(RuleKind::DfrSgl).run().unwrap();
    let no_screen =
        PathRunner::new(&gd.dataset, cfg).rule(RuleKind::NoScreen).run().unwrap();
    // Same bound the repo's DFR-vs-no-screen equivalence suite pins at
    // this tolerance (the criterion is relative β change, so two
    // differently-warm-started solves agree to ~tol-scale, not exactly).
    let d = screened.l2_distance_to(&no_screen);
    assert!(d <= 5e-4, "escalated path drifted from no-screen: ℓ₂ = {d}");
    // Whatever route each point took, the result is certified: worst-case
    // status must still be a success (Converged or KktCapHit).
    assert!(
        screened.metrics.worst_status().is_success(),
        "escalation left an uncertified point: {:?}",
        screened.metrics.worst_status()
    );
}

#[test]
fn statuses_flow_into_fit_reports() {
    let (rows, y) = good_problem(32, 50, 8);
    let mut fitter = small_model().fitter();
    let fit = fitter.fit_at(&Design::rows(&rows), &y, &[4, 4], Response::Linear, 5).unwrap();
    assert_eq!(fit.status(), SolveStatus::Converged);
    let csv = dfr::report::path_metrics_csv(&fit.path_fit.metrics);
    let mut lines = csv.lines();
    assert!(lines.next().unwrap_or_default().contains(",status,"));
    assert!(lines.next().unwrap_or_default().contains("converged"));
}
