//! Pathwise equivalence property tests for the persistent-workspace hot
//! loop: the workspace / cached-gather / residual-carried path must produce
//! coefficients numerically identical (ℓ₂ ≤ 1e-10) to a fresh-allocation
//! reference fit, for every screening rule, and a workspace reused across
//! fits and datasets must never leak state between them.

use dfr::data::SyntheticConfig;
use dfr::path::{PathConfig, PathRunner, PathWorkspace};
use dfr::screen::RuleKind;
use dfr::solver::SolverConfig;

fn data(seed: u64) -> dfr::data::GeneratedData {
    SyntheticConfig {
        n: 60,
        p: 80,
        groups: dfr::data::synthetic::GroupSpec::Even(8),
        ..SyntheticConfig::default()
    }
    .generate(seed)
}

fn cfg() -> PathConfig {
    PathConfig {
        path_len: 10,
        solver: SolverConfig { tol: 1e-9, max_iters: 50_000, ..Default::default() },
        ..PathConfig::default()
    }
}

/// The headline property: workspace reuse and the incremental reduced-design
/// cache change nothing about the solutions, for each rule family.
#[test]
fn workspace_path_matches_fresh_allocation_reference() {
    let gd = data(5);
    for rule in [
        RuleKind::DfrSgl,
        RuleKind::Sparsegl,
        RuleKind::GapSafeSeq,
        RuleKind::GapSafeDyn,
    ] {
        let reference = PathRunner::new(&gd.dataset, cfg())
            .rule(rule)
            .reference_alloc(true)
            .run()
            .unwrap();
        let fast = PathRunner::new(&gd.dataset, cfg())
            .rule(rule)
            .fixed_path(reference.lambdas.clone())
            .run()
            .unwrap();
        let d = fast.l2_distance_to(&reference);
        assert!(d <= 1e-10, "{}: workspace drift ℓ₂ = {d}", rule.name());
    }
}

/// Same property for the adaptive variant (aSGL weights flow through the
/// restricted penalty and the workspace identically).
#[test]
fn asgl_workspace_matches_reference() {
    let gd = data(6);
    let c = PathConfig { adaptive: Some((0.1, 0.1)), ..cfg() };
    let reference = PathRunner::new(&gd.dataset, c.clone())
        .rule(RuleKind::DfrAsgl)
        .reference_alloc(true)
        .run()
        .unwrap();
    let fast = PathRunner::new(&gd.dataset, c)
        .rule(RuleKind::DfrAsgl)
        .fixed_path(reference.lambdas.clone())
        .run()
        .unwrap();
    let d = fast.l2_distance_to(&reference);
    assert!(d <= 1e-10, "aSGL workspace drift ℓ₂ = {d}");
}

/// One workspace across many fits and *different datasets*: the reduced
/// design cache must detect the matrix change and the dirty solver buffers
/// must not affect results.
#[test]
fn workspace_reuse_across_fits_and_datasets_is_clean() {
    let gd_a = data(7);
    let gd_b = data(8); // same shape, different draw — worst case for stale caches
    let mut ws = PathWorkspace::default();

    let a_first = PathRunner::new(&gd_a.dataset, cfg())
        .rule(RuleKind::DfrSgl)
        .run_with_workspace(&mut ws)
        .unwrap();
    let b_shared = PathRunner::new(&gd_b.dataset, cfg())
        .rule(RuleKind::DfrSgl)
        .run_with_workspace(&mut ws)
        .unwrap();
    let b_fresh = PathRunner::new(&gd_b.dataset, cfg()).rule(RuleKind::DfrSgl).run().unwrap();
    assert!(
        b_shared.l2_distance_to(&b_fresh) <= 1e-12,
        "stale workspace state leaked across datasets"
    );

    // Back to the first dataset: must reproduce the original fit exactly.
    let a_again = PathRunner::new(&gd_a.dataset, cfg())
        .rule(RuleKind::DfrSgl)
        .run_with_workspace(&mut ws)
        .unwrap();
    assert!(
        a_again.l2_distance_to(&a_first) <= 1e-12,
        "workspace round-trip changed solutions"
    );
}

/// The cache actually does incremental work along a path (sanity check that
/// the equivalence above is not vacuous).
#[test]
fn reduced_design_cache_reuses_columns() {
    let gd = data(9);
    let mut ws = PathWorkspace::default();
    PathRunner::new(&gd.dataset, cfg())
        .rule(RuleKind::DfrSgl)
        .run_with_workspace(&mut ws)
        .unwrap();
    let total = ws.reduced.hits + ws.reduced.kept_cols + ws.reduced.copied_cols;
    assert!(total > 0, "reduced-design cache never used");
    // Incremental reuse (hits/kept prefix) is data-dependent at the path
    // level; the deterministic prefix-diff mechanism itself is covered by
    // linalg::tests::reduced_design_matches_fresh_gather. Here we just
    // surface the counters for bench logs.
    println!(
        "[cache] hits {}, kept cols {}, copied cols {}",
        ws.reduced.hits, ws.reduced.kept_cols, ws.reduced.copied_cols
    );
}
