//! Screening-safety gates: the contract each rule family must honor,
//! checked against a no-screen oracle on every (rule × loss × kernel)
//! cell.
//!
//! * **Safe rules** (TLFre, GAP-safe seq/dyn — `needs_kkt() == false`)
//!   may *never* discard a variable that is active in the oracle solution
//!   at the screened λ, at any path point. This is exact safety — set
//!   membership, not a distance tolerance.
//! * **Strong rules** (DFR, sparsegl) may err, but the KKT re-entry loop
//!   must repair every erroneous discard: final solutions within
//!   ℓ₂ ≤ 1e-8 of the oracle and identical supports.
//! * **Everyone** must end every path point KKT-clean: the
//!   [`dfr::testkit::KktAudit`] harness recomputes the stationarity
//!   residual of every accepted solution from scratch.
//! * Safe rules must take the coordinator's no-recheck fast path: zero
//!   KKT re-entry rounds and zero violations recorded, dense and sparse,
//!   while still matching the strong-rule solution.

use dfr::data::{Dataset, Response};
use dfr::linalg::{CenteredSparse, CscMatrix, DesignOps};
use dfr::loss::{Loss, LossKind};
use dfr::path::{compare_with_no_screen, PathConfig, PathRunner};
use dfr::prelude::Groups;
use dfr::rng::Rng;
use dfr::screen::{self, RuleKind, ScreenContext};
use dfr::solver::SolverConfig;
use dfr::testkit::KktAudit;

const SAFE_RULES: [RuleKind; 3] =
    [RuleKind::GapSafeSeq, RuleKind::GapSafeDyn, RuleKind::Tlfre];
const STRONG_RULES: [RuleKind; 2] = [RuleKind::DfrSgl, RuleKind::Sparsegl];

/// Genotype-like CSC design (mostly implicit zeros); `n > p` keeps the
/// squared loss strictly convex so the oracle optimum is unique.
fn genotype(seed: u64, n: usize, p: usize) -> CscMatrix {
    let mut rng = Rng::new(seed);
    let mut col_ptr = vec![0usize];
    let mut row_idx = Vec::new();
    let mut values = Vec::new();
    for _ in 0..p {
        let maf = 0.05 + 0.10 * rng.uniform();
        for i in 0..n {
            let dosage = (rng.bernoulli(maf) as u8 + rng.bernoulli(maf) as u8) as f64;
            if dosage > 0.0 {
                row_idx.push(i);
                values.push(dosage);
            }
        }
        col_ptr.push(row_idx.len());
    }
    CscMatrix::new(n, p, col_ptr, row_idx, values)
}

fn response(geno: &CscMatrix, seed: u64, kind: Response) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x5AFE);
    let p = geno.ncols();
    let beta_true: Vec<f64> =
        (0..p).map(|j| if j % 6 == 0 { rng.normal(0.0, 1.5) } else { 0.0 }).collect();
    let xb = geno.matvec(&beta_true);
    match kind {
        Response::Linear => xb.iter().map(|v| v + rng.normal(0.0, 0.3)).collect(),
        Response::Logistic => {
            let mean = xb.iter().sum::<f64>() / xb.len() as f64;
            xb.iter()
                .map(|v| if v - mean + rng.normal(0.0, 0.3) > 0.0 { 1.0 } else { 0.0 })
                .collect()
        }
    }
}

/// The same problem as a dense-kernel and a sparse-kernel [`Dataset`].
fn paired_datasets(seed: u64, kind: Response) -> (Dataset, Dataset) {
    let (n, p, gsize) = (60usize, 40usize, 5usize);
    let geno = genotype(seed, n, p);
    let mut y = response(&geno, seed, kind);
    if kind == Response::Linear {
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        y.iter_mut().for_each(|v| *v -= mean);
    }
    let groups = Groups::from_sizes(&vec![gsize; p / gsize]);
    let (dense_std, _) = geno.to_standardized_dense();
    let sparse = CenteredSparse::from_csc(&geno);
    let dense_ds = Dataset {
        x: dense_std.into(),
        y: y.clone(),
        groups: groups.clone(),
        response: kind,
        name: "safety-dense".into(),
    };
    let sparse_ds = Dataset {
        x: DesignOps::Sparse(sparse),
        y,
        groups,
        response: kind,
        name: "safety-sparse".into(),
    };
    (dense_ds, sparse_ds)
}

/// Oracle-grade solver settings: tight enough that the no-screen support
/// is the true support up to 1e-8.
fn cfg() -> PathConfig {
    PathConfig {
        path_len: 8,
        solver: SolverConfig { tol: 1e-12, max_iters: 200_000, ..Default::default() },
        ..PathConfig::default()
    }
}

/// A variable counted as active in the oracle solution (the inner solvers
/// produce exact zeros for inactive coordinates, so any meaningfully
/// nonzero entry is support).
const ACTIVE: f64 = 1e-8;

/// Exact safety: replay each safe rule between every pair of consecutive
/// oracle path points and assert no oracle-active variable at λ_{k+1} is
/// missing from the candidate set. Every (rule × loss × kernel) cell.
#[test]
fn safe_rules_never_discard_oracle_active_variables() {
    for kind in [Response::Linear, Response::Logistic] {
        let (dense_ds, sparse_ds) = paired_datasets(11, kind);
        for ds in [&dense_ds, &sparse_ds] {
            let oracle = PathRunner::new(ds, cfg())
                .rule(RuleKind::NoScreen)
                .run()
                .unwrap();
            let pen = PathRunner::new(ds, cfg()).rule(RuleKind::NoScreen).build_penalty();
            let loss = Loss::new(LossKind::for_response(kind), &ds.x, &ds.y);
            for rule in SAFE_RULES {
                for k in 0..oracle.lambdas.len() - 1 {
                    let grad_prev = loss.gradient(&oracle.betas[k]);
                    let ctx = ScreenContext {
                        penalty: &pen,
                        grad_prev: &grad_prev,
                        beta_prev: &oracle.betas[k],
                        lambda_prev: oracle.lambdas[k],
                        lambda_next: oracle.lambdas[k + 1],
                        x: ds.x.view(),
                        y: &ds.y,
                        response: kind,
                    };
                    let cands = screen::screen(rule, &ctx);
                    for (i, &b) in oracle.betas[k + 1].iter().enumerate() {
                        assert!(
                            b.abs() <= ACTIVE || cands.vars.binary_search(&i).is_ok(),
                            "{} ({kind:?}, {}): discarded oracle-active var {i} \
                             (β = {b:.3e}) at path point {}",
                            rule.name(),
                            ds.name,
                            k + 1
                        );
                    }
                }
            }
        }
    }
}

/// Strong rules may discard wrongly, but KKT re-entry must repair every
/// error: solutions within ℓ₂ ≤ 1e-8 of the oracle and identical supports
/// at every path point.
#[test]
fn strong_rule_discards_are_repaired_by_kkt_reentry() {
    for kind in [Response::Linear, Response::Logistic] {
        let (dense_ds, sparse_ds) = paired_datasets(12, kind);
        for ds in [&dense_ds, &sparse_ds] {
            for rule in STRONG_RULES {
                let c = compare_with_no_screen(ds, &cfg(), rule).unwrap();
                assert!(
                    c.l2_distance <= 1e-8,
                    "{} ({kind:?}, {}): ℓ₂ drift {} after KKT repair",
                    rule.name(),
                    ds.name,
                    c.l2_distance
                );
                for (k, (a, b)) in
                    c.screened.betas.iter().zip(&c.no_screen.betas).enumerate()
                {
                    for i in 0..a.len() {
                        // With ℓ₂ ≤ 1e-8 per point, a 1e-7-sized entry on
                        // one side forces a nonzero entry on the other.
                        assert!(
                            !(a[i].abs() > 1e-7 && b[i].abs() <= ACTIVE)
                                && !(b[i].abs() > 1e-7 && a[i].abs() <= ACTIVE),
                            "{} ({kind:?}, {}): support mismatch at point {k}, var {i}: \
                             screened {:.3e} vs oracle {:.3e}",
                            rule.name(),
                            ds.name,
                            a[i],
                            b[i]
                        );
                    }
                }
            }
        }
    }
}

/// Every rule ends every path point KKT-clean (stationarity residual ≤
/// tol, recomputed from scratch), and safe rules record zero re-entries.
#[test]
fn all_rules_end_every_path_point_kkt_clean() {
    let rules = [
        RuleKind::NoScreen,
        RuleKind::DfrSgl,
        RuleKind::Sparsegl,
        RuleKind::GapSafeSeq,
        RuleKind::GapSafeDyn,
        RuleKind::Tlfre,
    ];
    for kind in [Response::Linear, Response::Logistic] {
        let (dense_ds, sparse_ds) = paired_datasets(13, kind);
        for ds in [&dense_ds, &sparse_ds] {
            for rule in rules {
                let c = cfg();
                let fit = PathRunner::new(ds, c.clone()).rule(rule).run().unwrap();
                let audit = KktAudit::from_fit(ds, &c, &fit);
                audit.assert_clean(1e-6);
                if !rule.needs_kkt() {
                    assert_eq!(
                        audit.total_reentries(),
                        0,
                        "{} ({kind:?}, {}): safe rule recorded KKT re-entries",
                        rule.name(),
                        ds.name
                    );
                }
            }
        }
    }
}

/// The adaptive variant holds to the same audit standard.
#[test]
fn adaptive_fits_end_kkt_clean() {
    let (dense_ds, _) = paired_datasets(14, Response::Linear);
    let c = PathConfig { adaptive: Some((0.1, 0.1)), ..cfg() };
    for rule in [RuleKind::DfrAsgl, RuleKind::Tlfre] {
        let fit = PathRunner::new(&dense_ds, c.clone()).rule(rule).run().unwrap();
        let audit = KktAudit::from_fit(&dense_ds, &c, &fit);
        audit.assert_clean(1e-6);
        if !rule.needs_kkt() {
            assert_eq!(audit.total_reentries(), 0);
        }
    }
}

/// Fast-path regression: `needs_kkt() == false` rules take the no-recheck
/// branch (zero re-entry rounds, zero violations recorded) yet match the
/// strong-rule solution on the same λ grid — dense and sparse kernels.
#[test]
fn safe_rule_fast_path_matches_strong_solution() {
    let (dense_ds, sparse_ds) = paired_datasets(15, Response::Linear);
    for ds in [&dense_ds, &sparse_ds] {
        let strong = PathRunner::new(ds, cfg()).rule(RuleKind::DfrSgl).run().unwrap();
        for rule in SAFE_RULES {
            let fit = PathRunner::new(ds, cfg())
                .rule(rule)
                .fixed_path(strong.lambdas.clone())
                .run()
                .unwrap();
            assert_eq!(
                fit.metrics.total_kkt_reentries(),
                0,
                "{} ({}): fast path recorded re-entry rounds",
                rule.name(),
                ds.name
            );
            assert_eq!(
                fit.metrics.total_kkt_violations(),
                0,
                "{} ({}): fast path recorded violations",
                rule.name(),
                ds.name
            );
            let d = fit.l2_distance_to(&strong);
            assert!(
                d <= 1e-8,
                "{} ({}): safe fit drifted from strong solution: ℓ₂ = {d}",
                rule.name(),
                ds.name
            );
        }
    }
}
