//! End-to-end equivalence gates for the centered-implicit sparse solve
//! path: a CSC genotype-style design solved through the
//! [`dfr::linalg::CenteredSparse`] kernels must match the dense
//! standardized solve to ℓ₂ ≤ 1e-10 — for every screening rule, both
//! response families, pathwise and CV-grid — and must never materialize an
//! n×p dense standardized matrix (the witness counter).

use dfr::cv::{CvConfig, CvEngine};
use dfr::data::{Dataset, Response};
use dfr::linalg::{dense_materializations, CenteredSparse, CscMatrix, DesignOps};
use dfr::model_api::{Design, SglModel, SparseMode};
use dfr::path::{PathConfig, PathRunner};
use dfr::prelude::Groups;
use dfr::rng::Rng;
use dfr::screen::RuleKind;
use dfr::solver::SolverConfig;

/// Genotype-like CSC design: per-SNP minor-allele frequency in
/// [0.02, 0.12], dosages in {0, 1, 2} — mostly implicit zeros.
fn genotype(seed: u64, n: usize, p: usize) -> CscMatrix {
    let mut rng = Rng::new(seed);
    let mut col_ptr = vec![0usize];
    let mut row_idx = Vec::new();
    let mut values = Vec::new();
    for _ in 0..p {
        let maf = 0.02 + 0.10 * rng.uniform();
        for i in 0..n {
            let dosage = (rng.bernoulli(maf) as u8 + rng.bernoulli(maf) as u8) as f64;
            if dosage > 0.0 {
                row_idx.push(i);
                values.push(dosage);
            }
        }
        col_ptr.push(row_idx.len());
    }
    CscMatrix::new(n, p, col_ptr, row_idx, values)
}

/// Response from a sparse causal signal, computed off the raw CSC (no
/// densification anywhere in the fixture).
fn response(geno: &CscMatrix, seed: u64, kind: Response) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x5161);
    let p = geno.ncols();
    let beta_true: Vec<f64> =
        (0..p).map(|j| if j % 9 == 0 { rng.normal(0.0, 1.5) } else { 0.0 }).collect();
    let xb = geno.matvec(&beta_true);
    match kind {
        Response::Linear => xb.iter().map(|v| v + rng.normal(0.0, 0.3)).collect(),
        Response::Logistic => {
            let mean = xb.iter().sum::<f64>() / xb.len() as f64;
            xb.iter()
                .map(|v| if v - mean + rng.normal(0.0, 0.3) > 0.0 { 1.0 } else { 0.0 })
                .collect()
        }
    }
}

/// The same problem as two [`Dataset`]s: one on the dense standardized
/// matrix, one on the centered-implicit sparse design. Same (centered)
/// response, same grouping.
fn paired_datasets(seed: u64, kind: Response) -> (Dataset, Dataset) {
    let (n, p, gsize) = (60usize, 48usize, 6usize);
    let geno = genotype(seed, n, p);
    let mut y = response(&geno, seed, kind);
    if kind == Response::Linear {
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        y.iter_mut().for_each(|v| *v -= mean);
    }
    let group_sizes = vec![gsize; p / gsize];
    let groups = Groups::from_sizes(&group_sizes);
    let (dense_std, _) = geno.to_standardized_dense();
    let sparse = CenteredSparse::from_csc(&geno);
    let dense_ds = Dataset {
        x: dense_std.into(),
        y: y.clone(),
        groups: groups.clone(),
        response: kind,
        name: "geno-dense".into(),
    };
    let sparse_ds = Dataset {
        x: DesignOps::Sparse(sparse),
        y,
        groups,
        response: kind,
        name: "geno-sparse".into(),
    };
    (dense_ds, sparse_ds)
}

/// Solver settings tight enough that the comparison measures the kernels'
/// floating-point perturbation, not optimizer slack.
fn cfg() -> PathConfig {
    PathConfig {
        path_len: 8,
        solver: SolverConfig { tol: 1e-12, max_iters: 200_000, ..Default::default() },
        ..PathConfig::default()
    }
}

const RULES: [RuleKind; 4] = [
    RuleKind::DfrSgl,
    RuleKind::Sparsegl,
    RuleKind::GapSafeSeq,
    RuleKind::GapSafeDyn,
];

#[test]
fn pathwise_sparse_matches_dense_linear_all_rules() {
    let (dense_ds, sparse_ds) = paired_datasets(3, Response::Linear);
    for rule in RULES {
        let dense_fit = PathRunner::new(&dense_ds, cfg()).rule(rule).run().unwrap();
        let sparse_fit = PathRunner::new(&sparse_ds, cfg())
            .rule(rule)
            .fixed_path(dense_fit.lambdas.clone())
            .run()
            .unwrap();
        let d = sparse_fit.l2_distance_to(&dense_fit);
        assert!(d <= 1e-10, "{}: sparse vs dense drift ℓ₂ = {d}", rule.name());
    }
}

#[test]
fn pathwise_sparse_matches_dense_logistic_all_rules() {
    let (dense_ds, sparse_ds) = paired_datasets(4, Response::Logistic);
    for rule in RULES {
        let dense_fit = PathRunner::new(&dense_ds, cfg()).rule(rule).run().unwrap();
        let sparse_fit = PathRunner::new(&sparse_ds, cfg())
            .rule(rule)
            .fixed_path(dense_fit.lambdas.clone())
            .run()
            .unwrap();
        let d = sparse_fit.l2_distance_to(&dense_fit);
        assert!(d <= 1e-10, "{} logistic: drift ℓ₂ = {d}", rule.name());
    }
}

#[test]
fn asgl_sparse_matches_dense() {
    // Adaptive weights flow through the sparse PCA power iteration.
    let (dense_ds, sparse_ds) = paired_datasets(5, Response::Linear);
    let c = PathConfig { adaptive: Some((0.1, 0.1)), ..cfg() };
    let dense_fit =
        PathRunner::new(&dense_ds, c.clone()).rule(RuleKind::DfrAsgl).run().unwrap();
    let sparse_fit = PathRunner::new(&sparse_ds, c)
        .rule(RuleKind::DfrAsgl)
        .fixed_path(dense_fit.lambdas.clone())
        .run()
        .unwrap();
    let d = sparse_fit.l2_distance_to(&dense_fit);
    assert!(d <= 1e-10, "aSGL sparse vs dense drift ℓ₂ = {d}");
}

/// CV on a sparse dataset never densifies. Runs the whole engine at
/// `threads = 1` so every fold fit executes on the calling thread —
/// `parallel::par_map` is inline at one thread — and the thread-local
/// witness counter sees all of it.
#[test]
fn sparse_cv_never_materializes_dense() {
    let (_, sparse_ds) = paired_datasets(12, Response::Linear);
    let cv = CvConfig {
        folds: 3,
        path: PathConfig { path_len: 6, ..PathConfig::default() },
        rule: RuleKind::DfrSgl,
        seed: 7,
        threads: 1,
    };
    let engine = CvEngine::new(1);
    let before = dense_materializations();
    let cell = engine.cross_validate(&sparse_ds, &cv).unwrap();
    assert_eq!(
        dense_materializations(),
        before,
        "sparse CV materialized a dense design"
    );
    assert!(cell.cv_loss.iter().all(|v| v.is_finite()));
}

#[test]
fn cv_grid_sparse_matches_dense() {
    let (dense_ds, sparse_ds) = paired_datasets(6, Response::Linear);
    let cv = CvConfig { folds: 3, path: cfg(), rule: RuleKind::DfrSgl, seed: 7, threads: 2 };
    let engine = CvEngine::new(2);
    let (dense_cells, dense_best) =
        engine.grid_search(&dense_ds, &cv, &[0.6, 0.95], &[None]).unwrap();
    let (sparse_cells, sparse_best) =
        engine.grid_search(&sparse_ds, &cv, &[0.6, 0.95], &[None]).unwrap();
    assert_eq!(dense_best, sparse_best, "CV grid winners diverged");
    for (dc, sc) in dense_cells.iter().zip(&sparse_cells) {
        assert_eq!(dc.best_idx, sc.best_idx, "α={} best λ index diverged", dc.alpha);
        for (a, b) in dc.cv_loss.iter().zip(&sc.cv_loss) {
            assert!((a - b).abs() <= 1e-8, "α={}: CV loss {a} vs {b}", dc.alpha);
        }
    }
}

/// Fitter-level round trip: the same CSC design through `SparseMode::On`
/// and `SparseMode::Off` produces matching raw-scale coefficients.
#[test]
fn fitter_sparse_mode_matches_dense_mode() {
    let geno = genotype(7, 60, 48);
    let y = response(&geno, 7, Response::Linear);
    let sizes = vec![6usize; 8];
    let base = SglModel { path: cfg(), ..SglModel::default() };
    let dense_fit = SglModel { sparse: SparseMode::Off, ..base.clone() }
        .fitter()
        .fit_at(&Design::Csc(&geno), &y, &sizes, Response::Linear, 7)
        .unwrap();
    let sparse_fit = SglModel { sparse: SparseMode::On, ..base }
        .fitter()
        .fit_at(&Design::Csc(&geno), &y, &sizes, Response::Linear, 7)
        .unwrap();
    let d = dfr::linalg::l2_distance(&dense_fit.coefficients, &sparse_fit.coefficients);
    assert!(d <= 1e-8, "raw-scale coefficient drift ℓ₂ = {d}");
    assert!(
        (dense_fit.intercept - sparse_fit.intercept).abs() <= 1e-8,
        "intercept drift"
    );
}

/// The acceptance witness: a CSC design below the density threshold
/// completes `fit_path` without ever allocating an n×p dense standardized
/// matrix (the thread-local densify counter stays put), and the fitter
/// reports the centered-sparse kernel. A dense-mode fit of the same design
/// does densify — proving the witness is not vacuous.
#[test]
fn sparse_fit_never_materializes_dense() {
    if std::env::var("DFR_SPARSE_DENSITY").is_ok() {
        eprintln!("SKIP: DFR_SPARSE_DENSITY override active; Auto routing not asserted");
        return;
    }
    let geno = genotype(8, 80, 96);
    assert!(
        geno.density() <= 0.25,
        "fixture density {} above the default threshold",
        geno.density()
    );
    let y = response(&geno, 8, Response::Linear);
    let sizes = vec![6usize; 16];
    let model = SglModel {
        path: PathConfig { path_len: 10, ..PathConfig::default() },
        ..SglModel::default() // SparseMode::Auto
    };

    let mut fitter = model.fitter();
    let before = dense_materializations();
    fitter.fit_path(&Design::Csc(&geno), &y, &sizes, Response::Linear).unwrap();
    assert_eq!(
        dense_materializations(),
        before,
        "sparse solve path materialized a dense design"
    );
    assert_eq!(fitter.kernel_variant(), Some("centered-sparse"));

    // Dense mode on the same design must tick the counter (non-vacuity).
    let mut dense_model = model.clone();
    dense_model.sparse = SparseMode::Off;
    let mut dense_fitter = dense_model.fitter();
    let before = dense_materializations();
    dense_fitter.fit_path(&Design::Csc(&geno), &y, &sizes, Response::Linear).unwrap();
    assert!(dense_materializations() > before, "dense-mode fit did not densify");
    assert_eq!(dense_fitter.kernel_variant(), Some("dense"));
}

/// `SparseMode::Auto` routes by density: genotype-sparse designs go
/// centered-sparse, a fully dense CSC goes to the dense kernels.
#[test]
fn auto_mode_resolves_by_density() {
    let sparse = genotype(9, 40, 24);
    // Forced modes are threshold-independent.
    assert_eq!(Design::Csc(&sparse).resolved_kernel(SparseMode::Off), "dense");
    assert_eq!(
        Design::Csc(&sparse).resolved_kernel(SparseMode::On),
        "centered-sparse"
    );
    // Auto routing depends on the default threshold — skip under an
    // ambient DFR_SPARSE_DENSITY override.
    if std::env::var("DFR_SPARSE_DENSITY").is_ok() {
        eprintln!("SKIP: DFR_SPARSE_DENSITY override active; Auto routing not asserted");
        return;
    }
    assert_eq!(Design::Csc(&sparse).resolved_kernel(SparseMode::Auto), "centered-sparse");

    let mut rng = Rng::new(10);
    let dense_mat = dfr::linalg::Matrix::from_fn(40, 24, |_, _| 1.0 + rng.gauss());
    let dense_csc = CscMatrix::from_dense(&dense_mat, 0.0);
    assert!(dense_csc.density() > 0.25);
    assert_eq!(Design::Csc(&dense_csc).resolved_kernel(SparseMode::Auto), "dense");
}
