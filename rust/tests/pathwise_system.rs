//! System-level tests of the pathwise coordinator: failure injection,
//! degenerate inputs, rule-vs-rule consistency, logistic paths, CV
//! integration, and surrogate real-data smoke runs.

use dfr::data::synthetic::GroupSpec;
use dfr::data::{Response, SyntheticConfig};
use dfr::path::{compare_with_no_screen, PathConfig, PathRunner};
use dfr::screen::RuleKind;
use dfr::solver::{SolverConfig, SolverKind};

fn cfg(path_len: usize) -> PathConfig {
    PathConfig {
        path_len,
        solver: SolverConfig { tol: 1e-8, max_iters: 50_000, ..Default::default() },
        ..PathConfig::default()
    }
}

/// Pure-noise response: the model should stay (almost) empty and screening
/// should discard nearly everything — the sparsest regime of Fig. 2.
#[test]
fn pure_noise_keeps_input_proportion_tiny() {
    let gd = SyntheticConfig {
        n: 60,
        p: 120,
        groups: GroupSpec::Even(10),
        group_sparsity: 0.0, // generator clamps to ≥1 group but signal=0 kills it
        signal: 0.0,
        ..SyntheticConfig::default()
    }
    .generate(3);
    let fit = PathRunner::new(&gd.dataset, cfg(10)).rule(RuleKind::DfrSgl).run().unwrap();
    assert!(
        fit.metrics.input_proportion() < 0.5,
        "noise problem kept {}",
        fit.metrics.input_proportion()
    );
    assert_eq!(fit.metrics.failed_convergences(), 0);
}

/// Saturated signal (every group active): screening can't help much but
/// must not lose solutions — the saturation regime of Fig. 2.
#[test]
fn saturated_signal_still_correct() {
    let gd = SyntheticConfig {
        n: 80,
        p: 60,
        groups: GroupSpec::Even(6),
        group_sparsity: 1.0,
        var_sparsity: 1.0,
        ..SyntheticConfig::default()
    }
    .generate(4);
    let cmp = compare_with_no_screen(&gd.dataset, &cfg(8), RuleKind::DfrSgl).unwrap();
    assert!(cmp.l2_distance < 1e-4, "drift {}", cmp.l2_distance);
}

/// Single observation, heavy-tailed group sizes, p ≫ n.
#[test]
fn extreme_aspect_ratios_run() {
    for (n, p) in [(4usize, 60usize), (150, 10)] {
        let gd = SyntheticConfig {
            n,
            p,
            groups: GroupSpec::Even(5),
            ..SyntheticConfig::default()
        }
        .generate(5);
        let fit = PathRunner::new(&gd.dataset, cfg(6)).rule(RuleKind::DfrSgl).run().unwrap();
        assert_eq!(fit.betas.len(), 6);
    }
}

/// ATOS and FISTA produce the same pathwise solutions under DFR (the paper
/// stresses solver-independence of the rule).
#[test]
fn solver_independence_of_screening() {
    let gd = SyntheticConfig {
        n: 50,
        p: 60,
        groups: GroupSpec::Even(6),
        ..SyntheticConfig::default()
    }
    .generate(6);
    let mut c_f = cfg(8);
    c_f.solver.tol = 1e-10;
    let mut c_a = c_f.clone();
    c_a.solver.kind = SolverKind::Atos;
    let f = PathRunner::new(&gd.dataset, c_f).rule(RuleKind::DfrSgl).run().unwrap();
    let a = PathRunner::new(&gd.dataset, c_a)
        .rule(RuleKind::DfrSgl)
        .fixed_path(f.lambdas.clone())
        .run()
        .unwrap();
    assert!(f.l2_distance_to(&a) < 1e-3, "solver drift {}", f.l2_distance_to(&a));
}

/// Logistic model: all strong rules preserve solutions (Appendix D.6).
#[test]
fn logistic_rules_preserve_solutions() {
    let gd = SyntheticConfig {
        n: 100,
        p: 60,
        groups: GroupSpec::Even(6),
        response: Response::Logistic,
        ..SyntheticConfig::default()
    }
    .generate(7);
    for rule in [RuleKind::DfrSgl, RuleKind::Sparsegl] {
        let cmp = compare_with_no_screen(&gd.dataset, &cfg(8), rule).unwrap();
        assert!(
            cmp.l2_distance < 1e-3,
            "{} logistic drift {}",
            rule.name(),
            cmp.l2_distance
        );
        assert_eq!(cmp.screened.metrics.failed_convergences(), 0);
    }
}

/// Surrogate real datasets smoke-run at small scale with DFR-aSGL (the
/// Fig. 4 pipeline at reduced size).
#[test]
fn surrogate_real_data_smoke() {
    use dfr::data::real::{RealDatasetKind, SurrogateConfig};
    for kind in [RealDatasetKind::Celiac, RealDatasetKind::TrustExperts] {
        let ds = SurrogateConfig::scaled(kind, 0.02).generate();
        let mut c = cfg(6);
        c.path_end_ratio = 0.2;
        let fit = PathRunner::new(&ds, c).rule(RuleKind::DfrSgl).run().unwrap();
        assert_eq!(fit.betas.len(), 6, "{}", kind.name());
    }
}

/// KKT failure injection: force a broken Lipschitz assumption by taking a
/// huge λ step (λ_{k+1} ≪ λ_k); the KKT loop must recover the correct
/// solution anyway.
#[test]
fn giant_lambda_steps_are_recovered_by_kkt_loop() {
    let gd = SyntheticConfig {
        n: 60,
        p: 80,
        groups: GroupSpec::Even(8),
        ..SyntheticConfig::default()
    }
    .generate(8);
    let ds = &gd.dataset;
    // Build a 3-point path with a brutal 100× drop — the strong-rule
    // assumption |λ_{k+1} − λ_k| small is maximally violated.
    let pen = dfr::penalty::Penalty::sgl(ds.groups.clone(), 0.95);
    let loss = dfr::loss::Loss::new(dfr::loss::LossKind::Squared, &ds.x, &ds.y);
    let lam1 = dfr::path::lambda_max(&pen, &loss.gradient(&vec![0.0; ds.p()]));
    let path = vec![lam1, lam1 * 0.5, lam1 * 0.005];
    let mut c = cfg(3);
    c.solver.tol = 1e-10;
    let screened = PathRunner::new(ds, c.clone())
        .rule(RuleKind::DfrSgl)
        .fixed_path(path.clone())
        .run()
        .unwrap();
    let baseline = PathRunner::new(ds, c)
        .rule(RuleKind::NoScreen)
        .fixed_path(path)
        .run()
        .unwrap();
    let drift = screened.l2_distance_to(&baseline);
    assert!(drift < 1e-3, "KKT loop failed to recover: drift {drift}");
}

/// CV end-to-end with screening enabled on a logistic problem.
#[test]
fn cv_with_screening_logistic() {
    let gd = SyntheticConfig {
        n: 90,
        p: 40,
        groups: GroupSpec::Even(8),
        response: Response::Logistic,
        ..SyntheticConfig::default()
    }
    .generate(9);
    let cv = dfr::cv::CvConfig {
        folds: 3,
        path: PathConfig { path_len: 6, ..PathConfig::default() },
        rule: RuleKind::DfrSgl,
        threads: 2,
        ..Default::default()
    };
    let cell = dfr::cv::cross_validate(&gd.dataset, &cv).unwrap();
    assert!(cell.cv_loss.iter().all(|v| v.is_finite()));
}

/// Empty-ish model at the very start of the path: O_v can be empty for
/// several points without panicking.
#[test]
fn flat_path_start_handles_empty_optimization_sets() {
    let gd = SyntheticConfig {
        n: 40,
        p: 30,
        groups: GroupSpec::Even(5),
        signal: 0.1,
        ..SyntheticConfig::default()
    }
    .generate(10);
    let mut c = cfg(20);
    c.path_end_ratio = 0.9; // shallow path: many near-λ₁ points
    let fit = PathRunner::new(&gd.dataset, c).rule(RuleKind::DfrSgl).run().unwrap();
    assert_eq!(fit.betas.len(), 20);
}
