"""Repo-root pytest shim: the compile-path packages live under python/
(never installed — they only run at build time), so running
`pytest python/tests/` from the repo root needs python/ on sys.path.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
