#!/usr/bin/env bash
# Run one named bench and snapshot its machine-readable results to the
# repository root, so the cross-PR perf trajectory (docs/BENCHMARKS.md)
# actually accumulates committed BENCH_<name>.json files.
#
# Usage:
#   scripts/bench_snapshot.sh <bench-name> [extra cargo bench args...]
#
# Examples:
#   scripts/bench_snapshot.sh ablation_solver
#   DFR_BENCH_FULL=1 scripts/bench_snapshot.sh perf_hotpath
#
# The bench binary writes target/bench_results/BENCH_<name>.json (see
# src/bench_harness.rs); this script copies it to ./BENCH_<name>.json for
# committing alongside the change that produced it.

set -euo pipefail

name="${1:?usage: scripts/bench_snapshot.sh <bench-name> [cargo bench args...]}"
shift || true

root="$(cd "$(dirname "$0")/.." && pwd)"

(cd "$root/rust" && cargo bench --bench "$name" "$@")

src="$root/rust/target/bench_results/BENCH_${name}.json"
if [[ ! -f "$src" ]]; then
    echo "error: $src not found — did the bench call BenchTable::finish(\"$name\")?" >&2
    exit 1
fi

cp "$src" "$root/BENCH_${name}.json"
echo "snapshot: BENCH_${name}.json ($(wc -c <"$root/BENCH_${name}.json") bytes)"
