#!/usr/bin/env python3
"""Diff two BENCH_<name>.json snapshots row by row.

Joins the two files on (metric, setting, method) and reports the relative
change of each row's comparison statistic (median by default — robust to
a slow outlier repeat; --stat mean switches). Rows present in only one
file are listed separately, so a bench that silently dropped a cell shows
up in the diff instead of vanishing.

Exit code is 0 when no timing row regresses beyond --threshold (default
10%), 1 otherwise — so CI can gate on it:

    python3 scripts/bench_diff.py BENCH_ooc_path.baseline.json \
        rust/target/bench_results/BENCH_ooc_path.json --threshold 0.10

Only rows whose metric mentions seconds (case-insensitive "seconds",
"time (s)") count as timing rows for the gate; proportions, cardinalities
and ℓ₂ distances are reported but never fail the gate (they are
correctness tripwires for the test suite, not perf gates). Higher-is-
better rows ("speedup", "improvement factor", "GB/s", "GFLOP/s",
"rows/sec") regress when they *fall* by more than the threshold.

Stdlib only; schema documented in docs/BENCHMARKS.md.
"""

import argparse
import json
import math
import sys

TIMING_MARKERS = ("seconds", "time (s)")
HIGHER_IS_BETTER = ("speedup", "improvement factor", "gb/s", "gflop/s", "rows/sec")


def load_rows(path):
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"error: cannot read {path}: {e}")
    rows = doc.get("rows")
    if not isinstance(rows, list):
        sys.exit(f"error: {path}: no 'rows' array (not a BENCH_<name>.json?)")
    out = {}
    for row in rows:
        key = (row.get("metric"), row.get("setting"), row.get("method"))
        if None in key:
            sys.exit(f"error: {path}: row missing metric/setting/method: {row}")
        out[key] = row
    return doc.get("title", "<untitled>"), out


def is_timing(metric):
    m = metric.lower()
    return any(t in m for t in TIMING_MARKERS)


def higher_is_better(metric):
    m = metric.lower()
    return any(t in m for t in HIGHER_IS_BETTER)


def fmt(v):
    if v is None or (isinstance(v, float) and not math.isfinite(v)):
        return "null"
    return f"{v:.4g}"


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="baseline BENCH_<name>.json")
    ap.add_argument("candidate", help="candidate BENCH_<name>.json")
    ap.add_argument(
        "--stat",
        choices=("median", "mean"),
        default="median",
        help="statistic to compare (default: median)",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="relative regression that fails the gate (default: 0.10 = 10%%)",
    )
    args = ap.parse_args()

    base_title, base = load_rows(args.baseline)
    cand_title, cand = load_rows(args.candidate)
    print(f"baseline : {args.baseline}  ({base_title})")
    print(f"candidate: {args.candidate}  ({cand_title})")
    print(f"stat={args.stat}  gate=timing rows worse by >{args.threshold:.0%}\n")

    shared = sorted(set(base) & set(cand))
    only_base = sorted(set(base) - set(cand))
    only_cand = sorted(set(cand) - set(base))

    header = f"{'metric':<38} {'setting':<22} {'method':<16} {'base':>10} {'cand':>10} {'delta':>8}"
    print(header)
    print("-" * len(header))
    regressions = []
    for key in shared:
        metric, setting, method = key
        b, c = base[key].get(args.stat), cand[key].get(args.stat)
        if b is None or c is None or not (math.isfinite(b) and math.isfinite(c)):
            delta_s = "n/a"
        elif b == 0.0:
            delta_s = "new" if c != 0.0 else "0%"
        else:
            rel = (c - b) / abs(b)
            delta_s = f"{rel:+.1%}"
            gated = is_timing(metric) or higher_is_better(metric)
            worse = -rel if higher_is_better(metric) else rel
            if gated and worse > args.threshold:
                regressions.append((key, rel))
                delta_s += " !"
        print(f"{metric:<38.38} {setting:<22.22} {method:<16.16} {fmt(b):>10} {fmt(c):>10} {delta_s:>8}")

    for label, keys in (("only in baseline", only_base), ("only in candidate", only_cand)):
        if keys:
            print(f"\n{label} ({len(keys)} rows):")
            for metric, setting, method in keys:
                print(f"  {metric} | {setting} | {method}")

    if regressions:
        print(f"\nFAIL: {len(regressions)} gated row(s) regressed beyond {args.threshold:.0%}:")
        for (metric, setting, method), rel in regressions:
            print(f"  {metric} | {setting} | {method}: {rel:+.1%}")
        sys.exit(1)
    print(f"\nOK: no gated row regressed beyond {args.threshold:.0%} ({len(shared)} rows compared)")


if __name__ == "__main__":
    main()
