#!/usr/bin/env bash
# End-to-end smoke test of the `dfr serve` NDJSON loop: build the release
# binary, pipe a scripted fit → predict → stats → evict → shutdown session
# through it, and assert on the reply stream. CI runs this after the main
# test job; it is also the quickest local sanity check of the serving
# subsystem (`scripts/serve_smoke.sh`).

set -euo pipefail

root="$(cd "$(dirname "$0")/.." && pwd)"
(cd "$root/rust" && cargo build --release)
bin="$root/rust/target/release/dfr"

script="$(mktemp)"
out="$(mktemp)"
trap 'rm -f "$script" "$out"' EXIT

# Tiny deterministic regression problem: 12 rows x 4 features, two groups
# of two, y = x0 - x1 + 0.5*x2 exactly.
cat >"$script" <<'EOF'
{"verb":"fit","id":1,"tenant":"smoke","x":[[-2.5,-1,0.5,2],[1,2.5,-1.5,0],[-1,0.5,2,-2],[2.5,-1.5,0,1.5],[0.5,2,-2,-0.5],[-1.5,0,1.5,-2.5],[2,-2,-0.5,1],[0,1.5,-2.5,-1],[-2,-0.5,1,2.5],[1.5,-2.5,-1,0.5],[-0.5,1,2.5,-1.5],[-2.5,-1,0.5,2]],"y":[-1.25,-2.25,-0.5,4,-2.5,-0.75,3.75,-2.75,-1,3.5,-0.25,-1.25],"groups":[2,2],"lambda_idx":3}
{"verb":"predict","id":2,"tenant":"smoke","x":[[-2.5,-1,0.5,2],[1,2.5,-1.5,0]]}
{"verb":"stats","id":3}
{"verb":"evict","id":4,"tenant":"smoke"}
{"verb":"shutdown","id":5}
EOF

"$bin" serve --path-len 8 <"$script" >"$out"

fail() {
    echo "serve smoke FAILED: $1" >&2
    echo "--- replies ---" >&2
    cat "$out" >&2
    exit 1
}

expect() {
    grep -qF "$1" "$out" || fail "reply stream missing \`$1\`"
}

lines="$(wc -l <"$out")"
[[ "$lines" -eq 5 ]] || fail "expected 5 reply lines, got $lines"

expect '"verb":"fit","ok":true,"id":1,"tenant":"smoke"'
expect '"screening_fallback":false'
expect '"verb":"predict","ok":true,"id":2,"tenant":"smoke"'
expect '"predictions":['
expect '"verb":"stats","ok":true,"id":3'
expect '"uptime_seconds"'
expect '"prepared":{"entries":1'
expect '"verb":"evict","ok":true,"id":4,"tenant":"smoke"'
expect '"had_model":true'
expect '"verb":"shutdown","ok":true,"id":5'

# No reply may report ok:false.
if grep -qF '"ok":false' "$out"; then
    fail "a reply reported ok:false"
fi

echo "serve smoke OK ($lines replies)"
